//! The operator vocabulary.
//!
//! Modeled on the subset of PyTorch's ATen IR that the paper's models
//! exercise, plus the collectives that distribution strategies insert and
//! the custom ops that optimized kernels (our Pallas L1 kernels) appear as.
//! Every operator produces exactly one output tensor.
//!
//! The same type is the e-graph language: `Op` must be `Eq + Hash`, so float
//! attributes are stored as bit patterns ([`FBits`]) and integer attributes
//! as (possibly symbolic) [`Scalar`]s.

use crate::symbolic::{Scalar, Solver};
use anyhow::{bail, ensure, Result};
use std::fmt;

/// An `f64` wrapper that is `Eq + Hash` via its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FBits(pub u64);

impl FBits {
    pub fn new(v: f64) -> Self {
        FBits(v.to_bits())
    }
    pub fn get(self) -> f64 {
        f64::from_bits(self.0)
    }
}

impl fmt::Display for FBits {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.get())
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Op {
    // ---- structural / rearrangement (clean, §3.2) ----
    Identity,
    /// `x[.., start:end, ..]` along `dim`.
    Slice { dim: usize, start: Scalar, end: Scalar },
    /// n-ary concatenation along `dim`.
    Concat { dim: usize },
    Transpose { perm: Vec<usize> },
    Reshape { shape: Vec<Scalar> },
    /// Pad `dim` with `value` (`before`/`after` elements).
    Pad { dim: usize, before: Scalar, after: Scalar, value: FBits },
    /// n-ary elementwise sum: how partial results from ranks are combined.
    /// Clean as a *reduction* op (§3.2(ii)).
    SumN,

    // ---- elementwise arithmetic ----
    Add,
    Sub,
    Mul,
    Div,
    Maximum,
    Neg,
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Square,
    Tanh,
    Gelu,
    Silu,
    Sigmoid,
    Relu,
    /// Multiply by a compile-time scalar constant.
    Scale { c: FBits },
    /// Add a compile-time scalar constant.
    AddScalar { c: FBits },

    // ---- linear algebra ----
    /// Batched matmul `[..., m, k] x [..., k, n] -> [..., m, n]`.
    MatMul,

    // ---- reductions ----
    ReduceSum { dim: usize, keepdim: bool },
    ReduceMean { dim: usize, keepdim: bool },
    ReduceMax { dim: usize, keepdim: bool },

    // ---- NN compound ops (ATen-style fused ops with their own lemmas) ----
    Softmax { dim: usize },
    /// `(x, weight)` — RMS-normalize the last dim. Also the op our Pallas
    /// kernel captures to.
    RmsNorm { eps: FBits },
    /// `(x, weight, bias)` — layer norm over the last dim.
    LayerNorm { eps: FBits },
    /// `(x, cos, sin)` — rotary position embedding. `x: [..., s, d]`,
    /// `cos/sin: [s, d]`; rotate-half convention.
    Rope,
    /// `(table, ids)` — row gather.
    Embedding,
    /// `(pred, target)` — mean squared error, scalar output.
    MseLoss,

    // ---- collectives (appear in G_d; single-program capture form where a
    //      k-rank collective is a node with k rank inputs) ----
    /// k inputs -> elementwise sum (one replicated output).
    AllReduce { ranks: usize },
    /// k inputs -> concat along `dim` (one replicated output).
    AllGather { dim: usize, ranks: usize },
    /// k inputs -> `index`-th chunk of the elementwise sum along `dim`.
    ReduceScatter { dim: usize, ranks: usize, index: usize },

    // ---- pipeline-parallel stage boundaries (single-program capture of a
    //      point-to-point transfer; `chan` identifies the matching pair, one
    //      channel per (stage boundary, micro-batch)) ----
    /// Value leaving a pipeline stage on channel `chan`. Identity semantics.
    Send { chan: usize },
    /// Value entering the next pipeline stage from channel `chan`. Identity
    /// semantics *only* when wired to the matching `Send` — the
    /// `recv_of_send_identity` lemma requires equal channels, so crossed or
    /// stale boundary wiring never simplifies and fails refinement.
    Recv { chan: usize },

    // ---- MoE routing (data-dependent token-to-expert assignment) ----
    /// `(scores[rows, E]) -> mask[rows, E]`: 0/1 mask of the `k` largest
    /// entries per row (ties broken toward the lower expert index). The
    /// router decision itself — *not* clean: it computes.
    TopK { k: usize },
    /// `(x[rows, ..], router[rows, E]) -> [rows, ..]`: token scatter to one
    /// expert, keyed by the router tensor. Row `t` is `router[t, expert] ·
    /// x[t, ..]` for the first `capacity` assigned rows (router entry
    /// nonzero, counted in row order); later assigned rows are *silently
    /// zeroed* — the classic capacity-overflow token drop. Clean graphs set
    /// `capacity >= rows` so truncation can never bind, which is also the
    /// side-condition of every dispatch lemma.
    Dispatch { expert: usize, capacity: usize },
    /// `(weights[rows, experts], y_0, .., y_{experts-1}) -> [rows, cols]`:
    /// token gather from experts, keyed by the router tensor:
    /// `out[t, j] = Σ_e weights[t, e] · y_e[t, j]`. Expert outputs are
    /// matrix-shaped (`[rows, cols]`) — the rank the routing lemmas and the
    /// column-broadcast VJP are row-aligned for.
    Combine { experts: usize },

    /// Opaque custom operator (e.g. a fused kernel GraphGuard has no
    /// built-in lemma for; users supply lemmas per §6.5). Shape/semantics
    /// come from the custom-op registry.
    Custom { name: String },
}

/// Discriminant used by pattern matching in the e-graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpTag {
    Identity,
    Slice,
    Concat,
    Transpose,
    Reshape,
    Pad,
    SumN,
    Add,
    Sub,
    Mul,
    Div,
    Maximum,
    Neg,
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Square,
    Tanh,
    Gelu,
    Silu,
    Sigmoid,
    Relu,
    Scale,
    AddScalar,
    MatMul,
    ReduceSum,
    ReduceMean,
    ReduceMax,
    Softmax,
    RmsNorm,
    LayerNorm,
    Rope,
    Embedding,
    MseLoss,
    AllReduce,
    AllGather,
    ReduceScatter,
    Send,
    Recv,
    TopK,
    Dispatch,
    Combine,
    Custom,
}

impl Op {
    pub fn tag(&self) -> OpTag {
        match self {
            Op::Identity => OpTag::Identity,
            Op::Slice { .. } => OpTag::Slice,
            Op::Concat { .. } => OpTag::Concat,
            Op::Transpose { .. } => OpTag::Transpose,
            Op::Reshape { .. } => OpTag::Reshape,
            Op::Pad { .. } => OpTag::Pad,
            Op::SumN => OpTag::SumN,
            Op::Add => OpTag::Add,
            Op::Sub => OpTag::Sub,
            Op::Mul => OpTag::Mul,
            Op::Div => OpTag::Div,
            Op::Maximum => OpTag::Maximum,
            Op::Neg => OpTag::Neg,
            Op::Exp => OpTag::Exp,
            Op::Log => OpTag::Log,
            Op::Sqrt => OpTag::Sqrt,
            Op::Rsqrt => OpTag::Rsqrt,
            Op::Square => OpTag::Square,
            Op::Tanh => OpTag::Tanh,
            Op::Gelu => OpTag::Gelu,
            Op::Silu => OpTag::Silu,
            Op::Sigmoid => OpTag::Sigmoid,
            Op::Relu => OpTag::Relu,
            Op::Scale { .. } => OpTag::Scale,
            Op::AddScalar { .. } => OpTag::AddScalar,
            Op::MatMul => OpTag::MatMul,
            Op::ReduceSum { .. } => OpTag::ReduceSum,
            Op::ReduceMean { .. } => OpTag::ReduceMean,
            Op::ReduceMax { .. } => OpTag::ReduceMax,
            Op::Softmax { .. } => OpTag::Softmax,
            Op::RmsNorm { .. } => OpTag::RmsNorm,
            Op::LayerNorm { .. } => OpTag::LayerNorm,
            Op::Rope => OpTag::Rope,
            Op::Embedding => OpTag::Embedding,
            Op::MseLoss => OpTag::MseLoss,
            Op::AllReduce { .. } => OpTag::AllReduce,
            Op::AllGather { .. } => OpTag::AllGather,
            Op::ReduceScatter { .. } => OpTag::ReduceScatter,
            Op::Send { .. } => OpTag::Send,
            Op::Recv { .. } => OpTag::Recv,
            Op::TopK { .. } => OpTag::TopK,
            Op::Dispatch { .. } => OpTag::Dispatch,
            Op::Combine { .. } => OpTag::Combine,
            Op::Custom { .. } => OpTag::Custom,
        }
    }

    /// Display name matching the capture-side op names (json interchange).
    pub fn name(&self) -> &'static str {
        match self.tag() {
            OpTag::Identity => "identity",
            OpTag::Slice => "slice",
            OpTag::Concat => "concat",
            OpTag::Transpose => "transpose",
            OpTag::Reshape => "reshape",
            OpTag::Pad => "pad",
            OpTag::SumN => "sum",
            OpTag::Add => "add",
            OpTag::Sub => "sub",
            OpTag::Mul => "mul",
            OpTag::Div => "div",
            OpTag::Maximum => "maximum",
            OpTag::Neg => "neg",
            OpTag::Exp => "exp",
            OpTag::Log => "log",
            OpTag::Sqrt => "sqrt",
            OpTag::Rsqrt => "rsqrt",
            OpTag::Square => "square",
            OpTag::Tanh => "tanh",
            OpTag::Gelu => "gelu",
            OpTag::Silu => "silu",
            OpTag::Sigmoid => "sigmoid",
            OpTag::Relu => "relu",
            OpTag::Scale => "scale",
            OpTag::AddScalar => "add_scalar",
            OpTag::MatMul => "matmul",
            OpTag::ReduceSum => "reduce_sum",
            OpTag::ReduceMean => "reduce_mean",
            OpTag::ReduceMax => "reduce_max",
            OpTag::Softmax => "softmax",
            OpTag::RmsNorm => "rms_norm",
            OpTag::LayerNorm => "layer_norm",
            OpTag::Rope => "rope",
            OpTag::Embedding => "embedding",
            OpTag::MseLoss => "mse_loss",
            OpTag::AllReduce => "all_reduce",
            OpTag::AllGather => "all_gather",
            OpTag::ReduceScatter => "reduce_scatter",
            OpTag::Send => "send",
            OpTag::Recv => "recv",
            OpTag::TopK => "topk",
            OpTag::Dispatch => "dispatch",
            OpTag::Combine => "combine",
            OpTag::Custom => "custom",
        }
    }

    /// May this operator appear in a *clean* expression (§3.2)? Rearrangement
    /// ops plus shard-combining reductions. `Add` counts: combining two
    /// partial sums is exactly the reduction case; `Scale`/`Div` do NOT —
    /// needing them to reconstruct `G_s` outputs is the signature of the
    /// aux-loss and gradient-accumulation bugs (§6.2 bugs 2 and 6).
    ///
    /// `Dispatch`/`Combine` are *conditionally* clean: they rearrange and
    /// combine tokens keyed by their router operand, so an expression using
    /// them is a relation *guarded by a router predicate* — it only
    /// reconstructs `G_s` tensors because the router tensor it references is
    /// provably the router both graphs computed (single-program capture
    /// shares it; crossed router tags never become equal in the e-graph).
    /// `TopK` itself computes the routing decision and stays unclean.
    pub fn is_clean(&self) -> bool {
        matches!(
            self.tag(),
            OpTag::Identity
                | OpTag::Slice
                | OpTag::Concat
                | OpTag::Transpose
                | OpTag::Reshape
                | OpTag::Pad
                | OpTag::SumN
                | OpTag::Add
                | OpTag::AllReduce
                | OpTag::AllGather
                | OpTag::ReduceScatter
                | OpTag::Send
                | OpTag::Recv
                | OpTag::Dispatch
                | OpTag::Combine
        )
    }

    /// Is this an elementwise (pointwise, shape-preserving modulo broadcast)
    /// operator? Drives the generic "elementwise distributes over concat"
    /// lemma family.
    pub fn is_unary_elementwise(&self) -> bool {
        matches!(
            self.tag(),
            OpTag::Neg
                | OpTag::Exp
                | OpTag::Log
                | OpTag::Sqrt
                | OpTag::Rsqrt
                | OpTag::Square
                | OpTag::Tanh
                | OpTag::Gelu
                | OpTag::Silu
                | OpTag::Sigmoid
                | OpTag::Relu
                | OpTag::Scale
                | OpTag::AddScalar
                | OpTag::Identity
        )
    }

    pub fn is_binary_elementwise(&self) -> bool {
        matches!(self.tag(), OpTag::Add | OpTag::Sub | OpTag::Mul | OpTag::Div | OpTag::Maximum)
    }

    /// Output shape from input shapes. `solver` resolves symbolic attrs; pass
    /// `None` on graph-construction paths where attrs are concrete.
    pub fn infer_shape(&self, ins: &[&[i64]], solver: Option<&Solver>) -> Result<Vec<i64>> {
        let conc = |s: &Scalar| -> Result<i64> {
            if let Some(k) = s.as_const() {
                return Ok(k);
            }
            if let Some(sv) = solver {
                if let Some(k) = sv.concretize(&s.0) {
                    return Ok(k);
                }
            }
            bail!("cannot concretize symbolic scalar {:?}", s)
        };
        match self {
            Op::Identity => {
                ensure!(ins.len() == 1, "identity arity");
                Ok(ins[0].to_vec())
            }
            Op::Slice { dim, start, end } => {
                ensure!(ins.len() == 1, "slice arity");
                let (s, e) = (conc(start)?, conc(end)?);
                ensure!(*dim < ins[0].len(), "slice dim {dim} of {:?}", ins[0]);
                ensure!(
                    0 <= s && s <= e && e <= ins[0][*dim],
                    "slice [{s}:{e}] of size {}",
                    ins[0][*dim]
                );
                let mut out = ins[0].to_vec();
                out[*dim] = e - s;
                Ok(out)
            }
            Op::Concat { dim } => {
                ensure!(!ins.is_empty(), "concat arity");
                ensure!(*dim < ins[0].len(), "concat dim");
                let mut out = ins[0].to_vec();
                out[*dim] = 0;
                for shape in ins {
                    ensure!(shape.len() == out.len(), "concat rank mismatch");
                    for d in 0..out.len() {
                        if d == *dim {
                            out[d] += shape[d];
                        } else {
                            ensure!(shape[d] == ins[0][d], "concat dim {d} mismatch");
                        }
                    }
                }
                Ok(out)
            }
            Op::Transpose { perm } => {
                ensure!(ins.len() == 1, "transpose arity");
                ensure!(perm.len() == ins[0].len(), "perm rank");
                let mut seen = vec![false; perm.len()];
                for &p in perm {
                    ensure!(p < perm.len() && !seen[p], "bad perm {:?}", perm);
                    seen[p] = true;
                }
                Ok(perm.iter().map(|&p| ins[0][p]).collect())
            }
            Op::Reshape { shape } => {
                ensure!(ins.len() == 1, "reshape arity");
                let out: Vec<i64> = shape.iter().map(&conc).collect::<Result<_>>()?;
                let want: i64 = out.iter().product();
                let have: i64 = ins[0].iter().product();
                ensure!(want == have, "reshape {:?} -> {:?}", ins[0], out);
                Ok(out)
            }
            Op::Pad { dim, before, after, .. } => {
                ensure!(ins.len() == 1, "pad arity");
                ensure!(*dim < ins[0].len(), "pad dim");
                let (b, a) = (conc(before)?, conc(after)?);
                ensure!(b >= 0 && a >= 0, "negative pad");
                let mut out = ins[0].to_vec();
                out[*dim] += b + a;
                Ok(out)
            }
            Op::SumN => {
                ensure!(!ins.is_empty(), "sum arity");
                for shape in ins {
                    ensure!(*shape == ins[0], "sum shape mismatch {:?} vs {:?}", shape, ins[0]);
                }
                Ok(ins[0].to_vec())
            }
            op if op.is_binary_elementwise() => {
                ensure!(ins.len() == 2, "{} arity", op.name());
                crate::util::ndarray::broadcast_shapes(ins[0], ins[1])
            }
            op if op.is_unary_elementwise() => {
                ensure!(ins.len() == 1, "{} arity", op.name());
                Ok(ins[0].to_vec())
            }
            Op::MatMul => {
                ensure!(ins.len() == 2, "matmul arity");
                let (a, b) = (ins[0], ins[1]);
                ensure!(a.len() >= 2 && b.len() >= 2, "matmul rank");
                ensure!(
                    a[a.len() - 1] == b[b.len() - 2],
                    "matmul inner dims {:?} x {:?}",
                    a,
                    b
                );
                let batch_a: i64 = a[..a.len() - 2].iter().product();
                let batch_b: i64 = b[..b.len() - 2].iter().product();
                ensure!(
                    batch_a == batch_b || batch_a == 1 || batch_b == 1,
                    "matmul batch {:?} x {:?}",
                    a,
                    b
                );
                let mut out =
                    if batch_a >= batch_b { a[..a.len() - 2].to_vec() } else { b[..b.len() - 2].to_vec() };
                out.push(a[a.len() - 2]);
                out.push(b[b.len() - 1]);
                Ok(out)
            }
            Op::ReduceSum { dim, keepdim }
            | Op::ReduceMean { dim, keepdim }
            | Op::ReduceMax { dim, keepdim } => {
                ensure!(ins.len() == 1, "reduce arity");
                ensure!(*dim < ins[0].len(), "reduce dim {dim} of {:?}", ins[0]);
                let mut out = ins[0].to_vec();
                if *keepdim {
                    out[*dim] = 1;
                } else {
                    out.remove(*dim);
                }
                Ok(out)
            }
            Op::Softmax { dim } => {
                ensure!(ins.len() == 1, "softmax arity");
                ensure!(*dim < ins[0].len(), "softmax dim");
                Ok(ins[0].to_vec())
            }
            Op::RmsNorm { .. } => {
                ensure!(ins.len() == 2, "rms_norm wants (x, weight)");
                let d = *ins[0].last().ok_or_else(|| anyhow::anyhow!("rms_norm rank"))?;
                ensure!(ins[1] == [d], "rms_norm weight {:?} vs hidden {}", ins[1], d);
                Ok(ins[0].to_vec())
            }
            Op::LayerNorm { .. } => {
                ensure!(ins.len() == 3, "layer_norm wants (x, weight, bias)");
                let d = *ins[0].last().ok_or_else(|| anyhow::anyhow!("layer_norm rank"))?;
                ensure!(ins[1] == [d] && ins[2] == [d], "layer_norm params");
                Ok(ins[0].to_vec())
            }
            Op::Rope => {
                ensure!(ins.len() == 3, "rope wants (x, cos, sin)");
                let x = ins[0];
                ensure!(x.len() >= 2, "rope rank");
                let (s, d) = (x[x.len() - 2], x[x.len() - 1]);
                ensure!(ins[1] == [s, d] && ins[2] == [s, d], "rope cos/sin {:?} vs [{s},{d}]", ins[1]);
                ensure!(d % 2 == 0, "rope needs even head dim");
                Ok(x.to_vec())
            }
            Op::Embedding => {
                ensure!(ins.len() == 2, "embedding wants (table, ids)");
                ensure!(ins[0].len() == 2, "embedding table rank");
                let mut out = ins[1].to_vec();
                out.push(ins[0][1]);
                Ok(out)
            }
            Op::MseLoss => {
                ensure!(ins.len() == 2 && ins[0] == ins[1], "mse_loss shapes {:?} {:?}", ins[0], ins[1]);
                Ok(vec![])
            }
            Op::AllReduce { ranks } => {
                ensure!(ins.len() == *ranks, "all_reduce wants {ranks} inputs");
                for shape in ins {
                    ensure!(*shape == ins[0], "all_reduce shape mismatch");
                }
                Ok(ins[0].to_vec())
            }
            Op::AllGather { dim, ranks } => {
                Op::Concat { dim: *dim }.infer_shape(ins, solver).and_then(|out| {
                    ensure!(ins.len() == *ranks, "all_gather wants {ranks} inputs");
                    Ok(out)
                })
            }
            Op::ReduceScatter { dim, ranks, index } => {
                ensure!(ins.len() == *ranks, "reduce_scatter wants {ranks} inputs");
                for shape in ins {
                    ensure!(*shape == ins[0], "reduce_scatter shape mismatch");
                }
                ensure!(*dim < ins[0].len(), "reduce_scatter dim");
                ensure!(
                    ins[0][*dim] % *ranks as i64 == 0,
                    "reduce_scatter dim {} not divisible by {}",
                    ins[0][*dim],
                    ranks
                );
                ensure!(index < ranks, "reduce_scatter index");
                let mut out = ins[0].to_vec();
                out[*dim] /= *ranks as i64;
                Ok(out)
            }
            Op::Send { .. } | Op::Recv { .. } => {
                ensure!(ins.len() == 1, "{} arity", self.name());
                Ok(ins[0].to_vec())
            }
            Op::TopK { k } => {
                ensure!(ins.len() == 1, "topk arity");
                ensure!(ins[0].len() == 2, "topk wants [rows, experts], got {:?}", ins[0]);
                ensure!(
                    *k >= 1 && *k as i64 <= ins[0][1],
                    "topk k={k} over {} experts",
                    ins[0][1]
                );
                Ok(ins[0].to_vec())
            }
            Op::Dispatch { expert, capacity } => {
                ensure!(ins.len() == 2, "dispatch wants (x, router)");
                let (x, r) = (ins[0], ins[1]);
                ensure!(r.len() == 2, "dispatch router must be [rows, experts], got {r:?}");
                ensure!(!x.is_empty() && x[0] == r[0], "dispatch rows {:?} vs router {:?}", x, r);
                ensure!((*expert as i64) < r[1], "dispatch expert {expert} of {} experts", r[1]);
                ensure!(*capacity >= 1, "dispatch capacity must be >= 1");
                Ok(x.to_vec())
            }
            Op::Combine { experts } => {
                ensure!(*experts >= 1, "combine needs at least one expert");
                ensure!(
                    ins.len() == *experts + 1,
                    "combine wants (weights, {} expert outputs), got {} inputs",
                    experts,
                    ins.len()
                );
                let w = ins[0];
                ensure!(w.len() == 2, "combine weights must be [rows, experts], got {w:?}");
                ensure!(w[1] == *experts as i64, "combine weights {:?} vs {} experts", w, experts);
                let y = ins[1];
                ensure!(
                    y.len() == 2 && y[0] == w[0],
                    "combine expert outputs must be [rows, cols] matching the weights rows, \
                     got {:?} vs {:?}",
                    y,
                    w
                );
                for shape in &ins[1..] {
                    ensure!(*shape == y, "combine expert shape {:?} vs {:?}", shape, y);
                }
                Ok(y.to_vec())
            }
            Op::Custom { name } => {
                crate::lemmas::custom::registry_infer_shape(name, ins)
            }
            _ => unreachable!("infer_shape: unhandled {:?}", self),
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::Slice { dim, start, end } => {
                write!(f, "slice[dim={dim}")?;
                if let (Some(s), Some(e)) = (start.as_const(), end.as_const()) {
                    write!(f, ",{s}:{e}]")
                } else {
                    write!(f, ",sym]")
                }
            }
            Op::Concat { dim } => write!(f, "concat[dim={dim}]"),
            Op::Transpose { perm } => write!(f, "transpose{perm:?}"),
            Op::Scale { c } => write!(f, "scale[{c}]"),
            Op::AddScalar { c } => write!(f, "add_scalar[{c}]"),
            Op::ReduceScatter { dim, ranks, index } => {
                write!(f, "reduce_scatter[dim={dim},{index}/{ranks}]")
            }
            Op::AllGather { dim, ranks } => write!(f, "all_gather[dim={dim},{ranks}]"),
            Op::AllReduce { ranks } => write!(f, "all_reduce[{ranks}]"),
            Op::Send { chan } => write!(f, "send[ch={chan}]"),
            Op::Recv { chan } => write!(f, "recv[ch={chan}]"),
            Op::TopK { k } => write!(f, "topk[k={k}]"),
            Op::Dispatch { expert, capacity } => {
                write!(f, "dispatch[e={expert},cap={capacity}]")
            }
            Op::Combine { experts } => write!(f, "combine[E={experts}]"),
            Op::Custom { name } => write!(f, "custom[{name}]"),
            other => write!(f, "{}", other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sh(op: &Op, ins: &[&[i64]]) -> Vec<i64> {
        op.infer_shape(ins, None).unwrap()
    }

    #[test]
    fn structural_shapes() {
        assert_eq!(sh(&Op::Slice { dim: 1, start: 2.into(), end: 5.into() }, &[&[3, 8]]), vec![3, 3]);
        assert_eq!(sh(&Op::Concat { dim: 0 }, &[&[2, 4], &[3, 4]]), vec![5, 4]);
        assert_eq!(sh(&Op::Transpose { perm: vec![1, 0] }, &[&[2, 5]]), vec![5, 2]);
        assert_eq!(
            sh(&Op::Pad { dim: 0, before: 1.into(), after: 2.into(), value: FBits::new(0.0) }, &[&[4]]),
            vec![7]
        );
    }

    #[test]
    fn matmul_shapes() {
        assert_eq!(sh(&Op::MatMul, &[&[4, 6], &[6, 3]]), vec![4, 3]);
        assert_eq!(sh(&Op::MatMul, &[&[2, 4, 6], &[2, 6, 3]]), vec![2, 4, 3]);
        assert!(Op::MatMul.infer_shape(&[&[4, 6], &[5, 3]], None).is_err());
    }

    #[test]
    fn collective_shapes() {
        assert_eq!(sh(&Op::AllGather { dim: 0, ranks: 2 }, &[&[2, 4], &[2, 4]]), vec![4, 4]);
        assert_eq!(sh(&Op::AllReduce { ranks: 2 }, &[&[2, 4], &[2, 4]]), vec![2, 4]);
        assert_eq!(
            sh(&Op::ReduceScatter { dim: 0, ranks: 2, index: 1 }, &[&[4, 4], &[4, 4]]),
            vec![2, 4]
        );
        assert!(Op::ReduceScatter { dim: 0, ranks: 2, index: 1 }
            .infer_shape(&[&[5, 4], &[5, 4]], None)
            .is_err());
    }

    #[test]
    fn nn_shapes() {
        assert_eq!(sh(&Op::RmsNorm { eps: FBits::new(1e-5) }, &[&[2, 3, 8], &[8]]), vec![2, 3, 8]);
        assert_eq!(sh(&Op::Rope, &[&[2, 4, 8], &[4, 8], &[4, 8]]), vec![2, 4, 8]);
        assert_eq!(sh(&Op::Embedding, &[&[100, 16], &[7]]), vec![7, 16]);
        assert_eq!(sh(&Op::MseLoss, &[&[4, 2], &[4, 2]]), Vec::<i64>::new());
    }

    #[test]
    fn send_recv_shapes_and_cleanliness() {
        assert_eq!(sh(&Op::Send { chan: 0 }, &[&[2, 4]]), vec![2, 4]);
        assert_eq!(sh(&Op::Recv { chan: 0 }, &[&[2, 4]]), vec![2, 4]);
        assert!(Op::Send { chan: 1 }.infer_shape(&[&[2], &[2]], None).is_err());
        assert!(Op::Send { chan: 3 }.is_clean());
        assert!(Op::Recv { chan: 3 }.is_clean());
        // boundary ops are NOT generic unary elementwise — distributing them
        // over concat would duplicate channel tags
        assert!(!Op::Send { chan: 0 }.is_unary_elementwise());
        assert_eq!(Op::Recv { chan: 2 }.tag(), OpTag::Recv);
        assert_eq!(Op::Send { chan: 2 }.name(), "send");
    }

    #[test]
    fn routing_shapes_and_cleanliness() {
        assert_eq!(sh(&Op::TopK { k: 2 }, &[&[4, 4]]), vec![4, 4]);
        assert!(Op::TopK { k: 5 }.infer_shape(&[&[4, 4]], None).is_err());
        assert!(Op::TopK { k: 1 }.infer_shape(&[&[4]], None).is_err());
        assert_eq!(sh(&Op::Dispatch { expert: 1, capacity: 4 }, &[&[4, 8], &[4, 2]]), vec![4, 8]);
        assert!(Op::Dispatch { expert: 2, capacity: 4 }
            .infer_shape(&[&[4, 8], &[4, 2]], None)
            .is_err());
        assert!(Op::Dispatch { expert: 0, capacity: 4 }
            .infer_shape(&[&[3, 8], &[4, 2]], None)
            .is_err());
        assert_eq!(
            sh(&Op::Combine { experts: 2 }, &[&[4, 2], &[4, 8], &[4, 8]]),
            vec![4, 8]
        );
        assert!(Op::Combine { experts: 2 }.infer_shape(&[&[4, 2], &[4, 8]], None).is_err());
        assert!(Op::Combine { experts: 3 }
            .infer_shape(&[&[4, 2], &[4, 8], &[4, 8], &[4, 8]], None)
            .is_err());
        // expert outputs are matrix-shaped only (the VJP's column broadcast
        // is row-aligned exactly for rank 2)
        assert!(Op::Combine { experts: 1 }
            .infer_shape(&[&[4, 1], &[4, 2, 3]], None)
            .is_err());
        // Dispatch/Combine are router-conditioned *clean* ops; TopK computes
        assert!(Op::Dispatch { expert: 0, capacity: 4 }.is_clean());
        assert!(Op::Combine { experts: 2 }.is_clean());
        assert!(!Op::TopK { k: 1 }.is_clean());
        // none of them are generic elementwise ops
        assert!(!Op::Dispatch { expert: 0, capacity: 4 }.is_unary_elementwise());
        assert!(!Op::Combine { experts: 2 }.is_binary_elementwise());
        assert_eq!(Op::TopK { k: 1 }.name(), "topk");
        assert_eq!(Op::Dispatch { expert: 0, capacity: 4 }.tag(), OpTag::Dispatch);
    }

    #[test]
    fn clean_classification() {
        assert!(Op::Slice { dim: 0, start: 0.into(), end: 1.into() }.is_clean());
        assert!(Op::Concat { dim: 0 }.is_clean());
        assert!(Op::SumN.is_clean());
        assert!(Op::Add.is_clean());
        assert!(Op::AllGather { dim: 0, ranks: 2 }.is_clean());
        // scaling / division are computation — NOT clean (bugs 2 & 6 hinge on this)
        assert!(!Op::Scale { c: FBits::new(0.5) }.is_clean());
        assert!(!Op::Div.is_clean());
        assert!(!Op::MatMul.is_clean());
        assert!(!Op::Softmax { dim: 1 }.is_clean());
    }

    #[test]
    fn symbolic_slice_with_solver() {
        use crate::symbolic::{LinExpr, SymTable};
        let mut t = SymTable::new();
        let n = t.intern("n");
        let mut solver = Solver::new();
        solver.assert_eq(&LinExpr::sym(n), &LinExpr::constant(5));
        let op = Op::Slice { dim: 0, start: 0.into(), end: Scalar::sym(n) };
        assert!(op.infer_shape(&[&[8]], None).is_err());
        assert_eq!(op.infer_shape(&[&[8]], Some(&solver)).unwrap(), vec![5]);
    }
}
