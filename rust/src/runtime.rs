//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`, produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! This is the request-path side of the three-layer architecture: Python/JAX
//! runs once at build time to lower the L2 model (with its L1 Pallas
//! kernels, interpret-lowered) to HLO *text*; this module compiles and runs
//! it with zero Python involvement. HLO text — not a serialized
//! HloModuleProto — is the interchange format because jax ≥ 0.5 emits
//! 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Used by `examples/cross_validate.rs`: execute `G_s` and `G_d` artifacts
//! on consistent inputs and check that the inferred `R_o` reconstructs the
//! sequential outputs from the distributed ones.

use crate::util::ndarray::NdArray;
use anyhow::{Context, Result};

pub struct Runtime {
    client: xla::PjRtClient,
}

pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this image).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &str) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path}"))?;
        Ok(LoadedModule { exe, name: path.to_string() })
    }
}

impl LoadedModule {
    /// Execute with f32 inputs; returns the flattened tuple of outputs.
    /// (aot.py lowers with `return_tuple=True`, so results are one tuple.)
    pub fn execute(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| {
                let shape: Vec<i64> = a.shape().to_vec();
                xla::Literal::vec1(a.data()).reshape(&shape).context("literal reshape")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().context("result shape")?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data: Vec<f32> = lit.to_vec().context("result data")?;
                NdArray::new(dims, data)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trips are covered by `examples/cross_validate.rs` and the
    // integration test `tests/runtime_pjrt.rs` (they need artifacts/ built
    // by `make artifacts`). Unit scope here: literal conversion helpers are
    // exercised indirectly; nothing to test without a compiled module.
}
