//! Numeric interpreter for operators, expressions and whole graphs.
//!
//! Three consumers: lemma validation (every rewrite rule is spot-checked on
//! random tensors), relation soundness checks (an inferred `R_o` is replayed
//! numerically to confirm it reconstructs `G_s`'s outputs), and the
//! `cross_validate` example which compares against PJRT-executed HLO.

use super::{Expr, TensorRef};
use crate::ir::{Graph, Op, TensorId};
use crate::util::ndarray::NdArray;
use anyhow::{bail, ensure, Context, Result};
use rustc_hash::FxHashMap;

/// Evaluate a single operator application.
pub fn eval_op(op: &Op, args: &[&NdArray]) -> Result<NdArray> {
    let unary = |f: fn(f32) -> f32| -> Result<NdArray> {
        ensure!(args.len() == 1, "{} arity", op.name());
        Ok(args[0].map(f))
    };
    match op {
        // stage-boundary transfers move the value unchanged; which *wiring*
        // is correct is the checker's problem, not the interpreter's
        Op::Identity | Op::Send { .. } | Op::Recv { .. } => unary(|x| x),
        Op::Neg => unary(|x| -x),
        Op::Exp => unary(f32::exp),
        Op::Log => unary(f32::ln),
        Op::Sqrt => unary(f32::sqrt),
        Op::Rsqrt => unary(|x| 1.0 / x.sqrt()),
        Op::Square => unary(|x| x * x),
        Op::Tanh => unary(f32::tanh),
        Op::Sigmoid => unary(|x| 1.0 / (1.0 + (-x).exp())),
        Op::Relu => unary(|x| x.max(0.0)),
        Op::Gelu => unary(|x| {
            0.5 * x * (1.0 + ((2.0f32 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
        }),
        Op::Silu => unary(|x| x / (1.0 + (-x).exp())),
        Op::Scale { c } => {
            let c = c.get() as f32;
            unary_dyn(args, move |x| x * c)
        }
        Op::AddScalar { c } => {
            let c = c.get() as f32;
            unary_dyn(args, move |x| x + c)
        }
        Op::Add => binop(args, |a, b| a + b),
        Op::Sub => binop(args, |a, b| a - b),
        Op::Mul => binop(args, |a, b| a * b),
        Op::Div => binop(args, |a, b| a / b),
        Op::Maximum => binop(args, f32::max),
        Op::SumN | Op::AllReduce { .. } => {
            ensure!(!args.is_empty(), "sum arity");
            let mut acc = args[0].clone();
            for a in &args[1..] {
                acc = acc.zip(a, |x, y| x + y)?;
            }
            Ok(acc)
        }
        Op::MatMul => {
            ensure!(args.len() == 2, "matmul arity");
            args[0].matmul(args[1])
        }
        Op::Slice { dim, start, end } => {
            ensure!(args.len() == 1, "slice arity");
            args[0].slice(*dim, const_of(start)?, const_of(end)?)
        }
        Op::Concat { dim } => NdArray::concat(&args.to_vec(), *dim),
        Op::AllGather { dim, .. } => NdArray::concat(&args.to_vec(), *dim),
        Op::Transpose { perm } => {
            ensure!(args.len() == 1, "transpose arity");
            args[0].transpose(perm)
        }
        Op::Reshape { shape } => {
            ensure!(args.len() == 1, "reshape arity");
            let dims: Vec<i64> = shape.iter().map(const_of).collect::<Result<_>>()?;
            args[0].reshape(dims)
        }
        Op::Pad { dim, before, after, value } => {
            ensure!(args.len() == 1, "pad arity");
            args[0].pad(*dim, const_of(before)?, const_of(after)?, value.get() as f32)
        }
        Op::ReduceSum { dim, keepdim } => args[0].sum_dim(*dim, *keepdim),
        Op::ReduceMean { dim, keepdim } => args[0].mean_dim(*dim, *keepdim),
        Op::ReduceMax { dim, keepdim } => args[0].max_dim(*dim, *keepdim),
        Op::Softmax { dim } => {
            ensure!(args.len() == 1, "softmax arity");
            let x = args[0];
            let max = x.max_dim(*dim, true)?;
            let shifted = x.zip(&max, |a, m| (a - m).exp())?;
            let denom = shifted.sum_dim(*dim, true)?;
            shifted.zip(&denom, |e, d| e / d)
        }
        Op::RmsNorm { eps } => {
            ensure!(args.len() == 2, "rms_norm arity");
            let (x, w) = (args[0], args[1]);
            let last = x.ndim() - 1;
            let ms = x.map(|v| v * v).mean_dim(last, true)?;
            let eps = eps.get() as f32;
            let normed = x.zip(&ms, move |v, m| v / (m + eps).sqrt())?;
            normed.zip(w, |v, wi| v * wi)
        }
        Op::LayerNorm { eps } => {
            ensure!(args.len() == 3, "layer_norm arity");
            let (x, w, b) = (args[0], args[1], args[2]);
            let last = x.ndim() - 1;
            let mean = x.mean_dim(last, true)?;
            let centered = x.zip(&mean, |v, m| v - m)?;
            let var = centered.map(|v| v * v).mean_dim(last, true)?;
            let eps = eps.get() as f32;
            let normed = centered.zip(&var, move |v, s| v / (s + eps).sqrt())?;
            normed.zip(w, |v, wi| v * wi)?.zip(b, |v, bi| v + bi)
        }
        Op::Rope => {
            ensure!(args.len() == 3, "rope arity");
            let (x, cos, sin) = (args[0], args[1], args[2]);
            let last = x.ndim() - 1;
            let d = *x.shape().last().unwrap();
            ensure!(d % 2 == 0, "rope head dim");
            // rotate_half(x) = concat(-x2, x1)
            let x1 = x.slice(last, 0, d / 2)?;
            let x2 = x.slice(last, d / 2, d)?;
            let rot = NdArray::concat(&[&x2.map(|v| -v), &x1], last)?;
            let a = x.zip(cos, |v, c| v * c)?;
            let b = rot.zip(sin, |v, s| v * s)?;
            a.zip(&b, |p, q| p + q)
        }
        Op::Embedding => {
            ensure!(args.len() == 2, "embedding arity");
            args[0].gather_rows(args[1])
        }
        Op::MseLoss => {
            ensure!(args.len() == 2, "mse arity");
            let d = args[0].zip(args[1], |a, b| (a - b) * (a - b))?;
            let n = d.len() as f32;
            Ok(NdArray::scalar(d.data().iter().sum::<f32>() / n))
        }
        Op::ReduceScatter { dim, ranks, index } => {
            let sum = eval_op(&Op::SumN, args)?;
            let chunk = sum.shape()[*dim] / *ranks as i64;
            sum.slice(*dim, *index as i64 * chunk, (*index as i64 + 1) * chunk)
        }
        Op::TopK { k } => {
            ensure!(args.len() == 1, "topk arity");
            let x = args[0];
            ensure!(x.ndim() == 2, "topk rank");
            let (rows, e) = (x.shape()[0] as usize, x.shape()[1] as usize);
            let mut out = NdArray::zeros(x.shape().to_vec());
            for t in 0..rows {
                let row = &x.data()[t * e..(t + 1) * e];
                let mut idx: Vec<usize> = (0..e).collect();
                // largest first; ties broken toward the lower expert index
                idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
                for &j in idx.iter().take(*k) {
                    out.data_mut()[t * e + j] = 1.0;
                }
            }
            Ok(out)
        }
        Op::Dispatch { expert, capacity } => {
            ensure!(args.len() == 2, "dispatch arity");
            let (x, r) = (args[0], args[1]);
            ensure!(r.ndim() == 2, "dispatch router rank");
            let (rows, e) = (r.shape()[0] as usize, r.shape()[1] as usize);
            ensure!(
                rows > 0 && x.ndim() >= 1 && x.shape()[0] as usize == rows,
                "dispatch rows {:?} vs router {:?}",
                x.shape(),
                r.shape()
            );
            let inner = x.len() / rows;
            let mut out = NdArray::zeros(x.shape().to_vec());
            // assigned tokens beyond `capacity` (in row order) are silently
            // dropped — the capacity-overflow behavior the mutation operator
            // `capacity_truncate_silent` exploits
            let mut used = 0usize;
            for t in 0..rows {
                let w = r.data()[t * e + *expert];
                if w != 0.0 {
                    if used < *capacity {
                        for j in 0..inner {
                            out.data_mut()[t * inner + j] = w * x.data()[t * inner + j];
                        }
                    }
                    used += 1;
                }
            }
            Ok(out)
        }
        Op::Combine { experts } => {
            ensure!(args.len() == *experts + 1, "combine arity");
            let w = args[0];
            ensure!(
                w.ndim() == 2 && w.shape()[1] == *experts as i64,
                "combine weights shape {:?}",
                w.shape()
            );
            let rows = w.shape()[0] as usize;
            let y0 = args[1];
            ensure!(
                rows > 0 && y0.ndim() >= 1 && y0.shape()[0] as usize == rows,
                "combine rows {:?} vs weights {:?}",
                y0.shape(),
                w.shape()
            );
            let inner = y0.len() / rows;
            let mut out = NdArray::zeros(y0.shape().to_vec());
            for (e, y) in args[1..].iter().enumerate() {
                ensure!(y.shape() == y0.shape(), "combine expert shape mismatch");
                for t in 0..rows {
                    let g = w.data()[t * *experts + e];
                    if g != 0.0 {
                        for j in 0..inner {
                            out.data_mut()[t * inner + j] += g * y.data()[t * inner + j];
                        }
                    }
                }
            }
            Ok(out)
        }
        Op::Custom { name } => crate::lemmas::custom::registry_eval(name, args),
    }
}

fn unary_dyn(args: &[&NdArray], f: impl Fn(f32) -> f32) -> Result<NdArray> {
    ensure!(args.len() == 1, "unary arity");
    Ok(args[0].map(f))
}

fn binop(args: &[&NdArray], f: impl Fn(f32, f32) -> f32) -> Result<NdArray> {
    ensure!(args.len() == 2, "binary arity");
    args[0].zip(args[1], f)
}

fn const_of(s: &crate::symbolic::Scalar) -> Result<i64> {
    s.as_const().ok_or_else(|| anyhow::anyhow!("symbolic scalar in numeric eval"))
}

/// Environment mapping leaf tensors to values.
pub type Env = FxHashMap<TensorRef, NdArray>;

/// Evaluate an expression under `env`.
pub fn eval_expr(e: &Expr, env: &Env) -> Result<NdArray> {
    match e {
        Expr::Leaf(t) => env
            .get(t)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unbound leaf {:?} in expression", t)),
        Expr::Op(op, args) => {
            let vals: Vec<NdArray> =
                args.iter().map(|a| eval_expr(a, env)).collect::<Result<_>>()?;
            let refs: Vec<&NdArray> = vals.iter().collect();
            eval_op(op, &refs).with_context(|| format!("evaluating {}", op))
        }
    }
}

/// Evaluate an entire graph given values for its inputs; returns values for
/// every tensor (by `TensorId`).
pub fn eval_graph(g: &Graph, inputs: &FxHashMap<TensorId, NdArray>) -> Result<Vec<NdArray>> {
    let mut vals: Vec<Option<NdArray>> = vec![None; g.num_tensors()];
    for &i in &g.inputs {
        let v = inputs
            .get(&i)
            .ok_or_else(|| anyhow::anyhow!("missing input '{}'", g.tensor(i).name))?;
        ensure!(
            v.shape() == g.shape(i),
            "input '{}' shape {:?} != declared {:?}",
            g.tensor(i).name,
            v.shape(),
            g.shape(i)
        );
        vals[i as usize] = Some(v.clone());
    }
    for nid in g.topo_order() {
        let node = g.node(nid);
        let args: Vec<&NdArray> = node
            .inputs
            .iter()
            .map(|&t| {
                vals[t as usize]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("tensor '{}' unset", g.tensor(t).name))
            })
            .collect::<Result<_>>()?;
        let out = eval_op(&node.op, &args).with_context(|| format!("node '{}'", node.name))?;
        vals[node.output as usize] = Some(out);
    }
    vals.into_iter()
        .enumerate()
        .map(|(i, v)| v.ok_or_else(|| anyhow::anyhow!("tensor {} never computed", i)))
        .collect()
}

/// Random input environment for a graph (deterministic per seed).
pub fn random_inputs(g: &Graph, seed: u64) -> FxHashMap<TensorId, NdArray> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut out = FxHashMap::default();
    for &i in &g.inputs {
        let t = g.tensor(i);
        let n: i64 = t.shape.iter().product();
        let data = match t.dtype {
            crate::ir::DType::F32 => rng.buf(n as usize, 0.5),
            // integral ids: keep them in a small safe range
            crate::ir::DType::I64 => (0..n).map(|_| rng.below(8) as f32).collect(),
        };
        out.insert(i, NdArray::new(t.shape.clone(), data).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::FBits;

    fn nd(shape: Vec<i64>, data: Vec<f32>) -> NdArray {
        NdArray::new(shape, data).unwrap()
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = nd(vec![2, 3], vec![1., 2., 3., 0., 0., 0.]);
        let s = eval_op(&Op::Softmax { dim: 1 }, &[&x]).unwrap();
        let sums = s.sum_dim(1, false).unwrap();
        assert!(sums.allclose(&nd(vec![2], vec![1., 1.]), 1e-5, 1e-6));
    }

    #[test]
    fn rmsnorm_matches_manual() {
        let x = nd(vec![1, 4], vec![1., 2., 3., 4.]);
        let w = nd(vec![4], vec![1., 1., 1., 1.]);
        let out = eval_op(&Op::RmsNorm { eps: FBits::new(0.0) }, &[&x, &w]).unwrap();
        let ms = (1. + 4. + 9. + 16.) / 4.0f32;
        let expect = x.map(|v| v / ms.sqrt());
        assert!(out.allclose(&expect, 1e-5, 1e-6));
    }

    #[test]
    fn rope_preserves_norm() {
        // RoPE is a rotation: per-pair L2 norm is preserved when cos²+sin²=1.
        let theta = 0.3f32;
        let x = nd(vec![1, 4], vec![1., 2., 3., 4.]);
        let cos = NdArray::full(vec![1, 4], theta.cos());
        let sin = NdArray::full(vec![1, 4], theta.sin());
        let out = eval_op(&Op::Rope, &[&x, &cos, &sin]).unwrap();
        let n_in: f32 = x.data().iter().map(|v| v * v).sum();
        let n_out: f32 = out.data().iter().map(|v| v * v).sum();
        assert!((n_in - n_out).abs() < 1e-4, "{n_in} vs {n_out}");
    }

    #[test]
    fn reduce_scatter_is_slice_of_sum() {
        let a = nd(vec![4], vec![1., 2., 3., 4.]);
        let b = nd(vec![4], vec![10., 20., 30., 40.]);
        let out = eval_op(&Op::ReduceScatter { dim: 0, ranks: 2, index: 1 }, &[&a, &b]).unwrap();
        assert_eq!(out.data(), &[33., 44.]);
    }

    #[test]
    fn mse_loss_scalar() {
        let a = nd(vec![2], vec![1., 3.]);
        let b = nd(vec![2], vec![0., 0.]);
        let out = eval_op(&Op::MseLoss, &[&a, &b]).unwrap();
        assert_eq!(out.shape(), &[] as &[i64]);
        assert!((out.data()[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn topk_masks_largest_with_lower_index_ties() {
        let s = nd(vec![2, 3], vec![0.1, 0.9, 0.5, 2.0, 2.0, -1.0]);
        let m1 = eval_op(&Op::TopK { k: 1 }, &[&s]).unwrap();
        assert_eq!(m1.data(), &[0., 1., 0., 1., 0., 0.], "row 1 tie → lower index");
        let m2 = eval_op(&Op::TopK { k: 2 }, &[&s]).unwrap();
        assert_eq!(m2.data(), &[0., 1., 1., 1., 1., 0.]);
    }

    #[test]
    fn dispatch_masks_rows_and_respects_capacity() {
        let x = nd(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let r = nd(vec![3, 2], vec![1., 0., 0., 1., 1., 0.]);
        // expert 0 takes rows 0 and 2
        let d = eval_op(&Op::Dispatch { expert: 0, capacity: 3 }, &[&x, &r]).unwrap();
        assert_eq!(d.data(), &[1., 2., 0., 0., 5., 6.]);
        // capacity 1: the second assigned row (row 2) is silently dropped
        let d1 = eval_op(&Op::Dispatch { expert: 0, capacity: 1 }, &[&x, &r]).unwrap();
        assert_eq!(d1.data(), &[1., 2., 0., 0., 0., 0.]);
        // non-0/1 router weights scale the dispatched rows
        let rw = nd(vec![3, 2], vec![0.5, 0., 0., 1., 2., 0.]);
        let dw = eval_op(&Op::Dispatch { expert: 0, capacity: 3 }, &[&x, &rw]).unwrap();
        assert_eq!(dw.data(), &[0.5, 1., 0., 0., 10., 12.]);
    }

    #[test]
    fn combine_is_router_weighted_sum() {
        let w = nd(vec![2, 2], vec![1., 0., 0.25, 0.75]);
        let y0 = nd(vec![2, 2], vec![1., 1., 4., 4.]);
        let y1 = nd(vec![2, 2], vec![2., 2., 8., 8.]);
        let out = eval_op(&Op::Combine { experts: 2 }, &[&w, &y0, &y1]).unwrap();
        assert_eq!(out.data(), &[1., 1., 7., 7.]);
    }

    #[test]
    fn dispatch_combine_topk_roundtrip() {
        // combine(m, dispatch(x,m;0), dispatch(x,m;1)) == x for a top-1 mask
        let s = nd(vec![3, 2], vec![0.3, 0.1, -0.5, 0.2, 1.0, 0.9]);
        let x = nd(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let m = eval_op(&Op::TopK { k: 1 }, &[&s]).unwrap();
        let d0 = eval_op(&Op::Dispatch { expert: 0, capacity: 3 }, &[&x, &m]).unwrap();
        let d1 = eval_op(&Op::Dispatch { expert: 1, capacity: 3 }, &[&x, &m]).unwrap();
        let back = eval_op(&Op::Combine { experts: 2 }, &[&m, &d0, &d1]).unwrap();
        assert!(back.allclose(&x, 0.0, 0.0), "top-1 dispatch/combine is exact");
    }

    #[test]
    fn graph_eval_end_to_end() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let c = g.matmul("c", a, b);
        let d = g.scale("d", c, 2.0);
        g.mark_output(d);
        let mut env = FxHashMap::default();
        env.insert(a, nd(vec![2, 2], vec![1., 2., 3., 4.]));
        env.insert(b, nd(vec![2, 2], vec![1., 0., 0., 1.]));
        let vals = eval_graph(&g, &env).unwrap();
        assert_eq!(vals[d as usize].data(), &[2., 4., 6., 8.]);
    }

    #[test]
    fn expr_eval_with_env() {
        let e = Expr::op(
            Op::Concat { dim: 0 },
            vec![Expr::leaf(TensorRef::d(0)), Expr::leaf(TensorRef::d(1))],
        );
        let mut env = Env::default();
        env.insert(TensorRef::d(0), nd(vec![1], vec![1.]));
        env.insert(TensorRef::d(1), nd(vec![1], vec![2.]));
        assert_eq!(eval_expr(&e, &env).unwrap().data(), &[1., 2.]);
        // unbound leaf errors
        let bad = Expr::leaf(TensorRef::d(7));
        assert!(eval_expr(&bad, &env).is_err());
    }
}
