//! Numeric interpreter for operators, expressions and whole graphs.
//!
//! Three consumers: lemma validation (every rewrite rule is spot-checked on
//! random tensors), relation soundness checks (an inferred `R_o` is replayed
//! numerically to confirm it reconstructs `G_s`'s outputs), and the
//! `cross_validate` example which compares against PJRT-executed HLO.

use super::{Expr, TensorRef};
use crate::ir::{Graph, Op, TensorId};
use crate::util::ndarray::NdArray;
use anyhow::{bail, ensure, Context, Result};
use rustc_hash::FxHashMap;

/// Evaluate a single operator application.
pub fn eval_op(op: &Op, args: &[&NdArray]) -> Result<NdArray> {
    let unary = |f: fn(f32) -> f32| -> Result<NdArray> {
        ensure!(args.len() == 1, "{} arity", op.name());
        Ok(args[0].map(f))
    };
    match op {
        // stage-boundary transfers move the value unchanged; which *wiring*
        // is correct is the checker's problem, not the interpreter's
        Op::Identity | Op::Send { .. } | Op::Recv { .. } => unary(|x| x),
        Op::Neg => unary(|x| -x),
        Op::Exp => unary(f32::exp),
        Op::Log => unary(f32::ln),
        Op::Sqrt => unary(f32::sqrt),
        Op::Rsqrt => unary(|x| 1.0 / x.sqrt()),
        Op::Square => unary(|x| x * x),
        Op::Tanh => unary(f32::tanh),
        Op::Sigmoid => unary(|x| 1.0 / (1.0 + (-x).exp())),
        Op::Relu => unary(|x| x.max(0.0)),
        Op::Gelu => unary(|x| {
            0.5 * x * (1.0 + ((2.0f32 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
        }),
        Op::Silu => unary(|x| x / (1.0 + (-x).exp())),
        Op::Scale { c } => {
            let c = c.get() as f32;
            unary_dyn(args, move |x| x * c)
        }
        Op::AddScalar { c } => {
            let c = c.get() as f32;
            unary_dyn(args, move |x| x + c)
        }
        Op::Add => binop(args, |a, b| a + b),
        Op::Sub => binop(args, |a, b| a - b),
        Op::Mul => binop(args, |a, b| a * b),
        Op::Div => binop(args, |a, b| a / b),
        Op::Maximum => binop(args, f32::max),
        Op::SumN | Op::AllReduce { .. } => {
            ensure!(!args.is_empty(), "sum arity");
            let mut acc = args[0].clone();
            for a in &args[1..] {
                acc = acc.zip(a, |x, y| x + y)?;
            }
            Ok(acc)
        }
        Op::MatMul => {
            ensure!(args.len() == 2, "matmul arity");
            args[0].matmul(args[1])
        }
        Op::Slice { dim, start, end } => {
            ensure!(args.len() == 1, "slice arity");
            args[0].slice(*dim, const_of(start)?, const_of(end)?)
        }
        Op::Concat { dim } => NdArray::concat(&args.to_vec(), *dim),
        Op::AllGather { dim, .. } => NdArray::concat(&args.to_vec(), *dim),
        Op::Transpose { perm } => {
            ensure!(args.len() == 1, "transpose arity");
            args[0].transpose(perm)
        }
        Op::Reshape { shape } => {
            ensure!(args.len() == 1, "reshape arity");
            let dims: Vec<i64> = shape.iter().map(const_of).collect::<Result<_>>()?;
            args[0].reshape(dims)
        }
        Op::Pad { dim, before, after, value } => {
            ensure!(args.len() == 1, "pad arity");
            args[0].pad(*dim, const_of(before)?, const_of(after)?, value.get() as f32)
        }
        Op::ReduceSum { dim, keepdim } => args[0].sum_dim(*dim, *keepdim),
        Op::ReduceMean { dim, keepdim } => args[0].mean_dim(*dim, *keepdim),
        Op::ReduceMax { dim, keepdim } => args[0].max_dim(*dim, *keepdim),
        Op::Softmax { dim } => {
            ensure!(args.len() == 1, "softmax arity");
            let x = args[0];
            let max = x.max_dim(*dim, true)?;
            let shifted = x.zip(&max, |a, m| (a - m).exp())?;
            let denom = shifted.sum_dim(*dim, true)?;
            shifted.zip(&denom, |e, d| e / d)
        }
        Op::RmsNorm { eps } => {
            ensure!(args.len() == 2, "rms_norm arity");
            let (x, w) = (args[0], args[1]);
            let last = x.ndim() - 1;
            let ms = x.map(|v| v * v).mean_dim(last, true)?;
            let eps = eps.get() as f32;
            let normed = x.zip(&ms, move |v, m| v / (m + eps).sqrt())?;
            normed.zip(w, |v, wi| v * wi)
        }
        Op::LayerNorm { eps } => {
            ensure!(args.len() == 3, "layer_norm arity");
            let (x, w, b) = (args[0], args[1], args[2]);
            let last = x.ndim() - 1;
            let mean = x.mean_dim(last, true)?;
            let centered = x.zip(&mean, |v, m| v - m)?;
            let var = centered.map(|v| v * v).mean_dim(last, true)?;
            let eps = eps.get() as f32;
            let normed = centered.zip(&var, move |v, s| v / (s + eps).sqrt())?;
            normed.zip(w, |v, wi| v * wi)?.zip(b, |v, bi| v + bi)
        }
        Op::Rope => {
            ensure!(args.len() == 3, "rope arity");
            let (x, cos, sin) = (args[0], args[1], args[2]);
            let last = x.ndim() - 1;
            let d = *x.shape().last().unwrap();
            ensure!(d % 2 == 0, "rope head dim");
            // rotate_half(x) = concat(-x2, x1)
            let x1 = x.slice(last, 0, d / 2)?;
            let x2 = x.slice(last, d / 2, d)?;
            let rot = NdArray::concat(&[&x2.map(|v| -v), &x1], last)?;
            let a = x.zip(cos, |v, c| v * c)?;
            let b = rot.zip(sin, |v, s| v * s)?;
            a.zip(&b, |p, q| p + q)
        }
        Op::Embedding => {
            ensure!(args.len() == 2, "embedding arity");
            args[0].gather_rows(args[1])
        }
        Op::MseLoss => {
            ensure!(args.len() == 2, "mse arity");
            let d = args[0].zip(args[1], |a, b| (a - b) * (a - b))?;
            let n = d.len() as f32;
            Ok(NdArray::scalar(d.data().iter().sum::<f32>() / n))
        }
        Op::ReduceScatter { dim, ranks, index } => {
            let sum = eval_op(&Op::SumN, args)?;
            let chunk = sum.shape()[*dim] / *ranks as i64;
            sum.slice(*dim, *index as i64 * chunk, (*index as i64 + 1) * chunk)
        }
        Op::Custom { name } => crate::lemmas::custom::registry_eval(name, args),
    }
}

fn unary_dyn(args: &[&NdArray], f: impl Fn(f32) -> f32) -> Result<NdArray> {
    ensure!(args.len() == 1, "unary arity");
    Ok(args[0].map(f))
}

fn binop(args: &[&NdArray], f: impl Fn(f32, f32) -> f32) -> Result<NdArray> {
    ensure!(args.len() == 2, "binary arity");
    args[0].zip(args[1], f)
}

fn const_of(s: &crate::symbolic::Scalar) -> Result<i64> {
    s.as_const().ok_or_else(|| anyhow::anyhow!("symbolic scalar in numeric eval"))
}

/// Environment mapping leaf tensors to values.
pub type Env = FxHashMap<TensorRef, NdArray>;

/// Evaluate an expression under `env`.
pub fn eval_expr(e: &Expr, env: &Env) -> Result<NdArray> {
    match e {
        Expr::Leaf(t) => env
            .get(t)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unbound leaf {:?} in expression", t)),
        Expr::Op(op, args) => {
            let vals: Vec<NdArray> =
                args.iter().map(|a| eval_expr(a, env)).collect::<Result<_>>()?;
            let refs: Vec<&NdArray> = vals.iter().collect();
            eval_op(op, &refs).with_context(|| format!("evaluating {}", op))
        }
    }
}

/// Evaluate an entire graph given values for its inputs; returns values for
/// every tensor (by `TensorId`).
pub fn eval_graph(g: &Graph, inputs: &FxHashMap<TensorId, NdArray>) -> Result<Vec<NdArray>> {
    let mut vals: Vec<Option<NdArray>> = vec![None; g.num_tensors()];
    for &i in &g.inputs {
        let v = inputs
            .get(&i)
            .ok_or_else(|| anyhow::anyhow!("missing input '{}'", g.tensor(i).name))?;
        ensure!(
            v.shape() == g.shape(i),
            "input '{}' shape {:?} != declared {:?}",
            g.tensor(i).name,
            v.shape(),
            g.shape(i)
        );
        vals[i as usize] = Some(v.clone());
    }
    for nid in g.topo_order() {
        let node = g.node(nid);
        let args: Vec<&NdArray> = node
            .inputs
            .iter()
            .map(|&t| {
                vals[t as usize]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("tensor '{}' unset", g.tensor(t).name))
            })
            .collect::<Result<_>>()?;
        let out = eval_op(&node.op, &args).with_context(|| format!("node '{}'", node.name))?;
        vals[node.output as usize] = Some(out);
    }
    vals.into_iter()
        .enumerate()
        .map(|(i, v)| v.ok_or_else(|| anyhow::anyhow!("tensor {} never computed", i)))
        .collect()
}

/// Random input environment for a graph (deterministic per seed).
pub fn random_inputs(g: &Graph, seed: u64) -> FxHashMap<TensorId, NdArray> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut out = FxHashMap::default();
    for &i in &g.inputs {
        let t = g.tensor(i);
        let n: i64 = t.shape.iter().product();
        let data = match t.dtype {
            crate::ir::DType::F32 => rng.buf(n as usize, 0.5),
            // integral ids: keep them in a small safe range
            crate::ir::DType::I64 => (0..n).map(|_| rng.below(8) as f32).collect(),
        };
        out.insert(i, NdArray::new(t.shape.clone(), data).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::FBits;

    fn nd(shape: Vec<i64>, data: Vec<f32>) -> NdArray {
        NdArray::new(shape, data).unwrap()
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = nd(vec![2, 3], vec![1., 2., 3., 0., 0., 0.]);
        let s = eval_op(&Op::Softmax { dim: 1 }, &[&x]).unwrap();
        let sums = s.sum_dim(1, false).unwrap();
        assert!(sums.allclose(&nd(vec![2], vec![1., 1.]), 1e-5, 1e-6));
    }

    #[test]
    fn rmsnorm_matches_manual() {
        let x = nd(vec![1, 4], vec![1., 2., 3., 4.]);
        let w = nd(vec![4], vec![1., 1., 1., 1.]);
        let out = eval_op(&Op::RmsNorm { eps: FBits::new(0.0) }, &[&x, &w]).unwrap();
        let ms = (1. + 4. + 9. + 16.) / 4.0f32;
        let expect = x.map(|v| v / ms.sqrt());
        assert!(out.allclose(&expect, 1e-5, 1e-6));
    }

    #[test]
    fn rope_preserves_norm() {
        // RoPE is a rotation: per-pair L2 norm is preserved when cos²+sin²=1.
        let theta = 0.3f32;
        let x = nd(vec![1, 4], vec![1., 2., 3., 4.]);
        let cos = NdArray::full(vec![1, 4], theta.cos());
        let sin = NdArray::full(vec![1, 4], theta.sin());
        let out = eval_op(&Op::Rope, &[&x, &cos, &sin]).unwrap();
        let n_in: f32 = x.data().iter().map(|v| v * v).sum();
        let n_out: f32 = out.data().iter().map(|v| v * v).sum();
        assert!((n_in - n_out).abs() < 1e-4, "{n_in} vs {n_out}");
    }

    #[test]
    fn reduce_scatter_is_slice_of_sum() {
        let a = nd(vec![4], vec![1., 2., 3., 4.]);
        let b = nd(vec![4], vec![10., 20., 30., 40.]);
        let out = eval_op(&Op::ReduceScatter { dim: 0, ranks: 2, index: 1 }, &[&a, &b]).unwrap();
        assert_eq!(out.data(), &[33., 44.]);
    }

    #[test]
    fn mse_loss_scalar() {
        let a = nd(vec![2], vec![1., 3.]);
        let b = nd(vec![2], vec![0., 0.]);
        let out = eval_op(&Op::MseLoss, &[&a, &b]).unwrap();
        assert_eq!(out.shape(), &[] as &[i64]);
        assert!((out.data()[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn graph_eval_end_to_end() {
        let mut g = Graph::new("t");
        let a = g.input("a", vec![2, 2]);
        let b = g.input("b", vec![2, 2]);
        let c = g.matmul("c", a, b);
        let d = g.scale("d", c, 2.0);
        g.mark_output(d);
        let mut env = FxHashMap::default();
        env.insert(a, nd(vec![2, 2], vec![1., 2., 3., 4.]));
        env.insert(b, nd(vec![2, 2], vec![1., 0., 0., 1.]));
        let vals = eval_graph(&g, &env).unwrap();
        assert_eq!(vals[d as usize].data(), &[2., 4., 6., 8.]);
    }

    #[test]
    fn expr_eval_with_env() {
        let e = Expr::op(
            Op::Concat { dim: 0 },
            vec![Expr::leaf(TensorRef::d(0)), Expr::leaf(TensorRef::d(1))],
        );
        let mut env = Env::default();
        env.insert(TensorRef::d(0), nd(vec![1], vec![1.]));
        env.insert(TensorRef::d(1), nd(vec![1], vec![2.]));
        assert_eq!(eval_expr(&e, &env).unwrap().data(), &[1., 2.]);
        // unbound leaf errors
        let bad = Expr::leaf(TensorRef::d(7));
        assert!(eval_expr(&bad, &env).is_err());
    }
}
