//! Human-readable expression rendering, used in reports, error localization
//! output, and the textual relation format (`expr::parse` is the inverse).

use super::{Expr, Side, TensorRef};
use crate::ir::{Graph, Op};
use std::fmt::Write;

/// Resolve leaf tensor names against the two graphs.
pub struct Namer<'a> {
    pub gs: &'a Graph,
    pub gd: &'a Graph,
}

impl Namer<'_> {
    pub fn name(&self, t: TensorRef) -> String {
        match t.side {
            Side::S => self.gs.tensor(t.id).name.clone(),
            Side::D => self.gd.tensor(t.id).name.clone(),
        }
    }
}

/// Render `e` as e.g. `sum(C_1, C_2)` / `slice(X; dim=0, start=0, end=4)`.
pub fn render(e: &Expr, namer: &Namer) -> String {
    let mut s = String::new();
    go(e, namer, &mut s);
    s
}

fn go(e: &Expr, namer: &Namer, out: &mut String) {
    match e {
        Expr::Leaf(t) => out.push_str(&namer.name(*t)),
        Expr::Op(op, args) => {
            out.push_str(head(op));
            out.push('(');
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                go(a, namer, out);
            }
            let attrs = attr_string(op);
            if !attrs.is_empty() {
                out.push_str("; ");
                out.push_str(&attrs);
            }
            out.push(')');
        }
    }
}

fn head(op: &Op) -> &str {
    match op {
        Op::Custom { name } => name,
        other => other.name(),
    }
}

/// `key=value` attribute list for ops that carry attributes.
pub fn attr_string(op: &Op) -> String {
    let mut s = String::new();
    let mut kv = |k: &str, v: String| {
        if !s.is_empty() {
            s.push_str(", ");
        }
        let _ = write!(s, "{k}={v}");
    };
    match op {
        Op::Slice { dim, start, end } => {
            kv("dim", dim.to_string());
            kv("start", scalar_str(start));
            kv("end", scalar_str(end));
        }
        Op::Concat { dim } | Op::Softmax { dim } => kv("dim", dim.to_string()),
        Op::Transpose { perm } => kv(
            "perm",
            format!("[{}]", perm.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",")),
        ),
        Op::Reshape { shape } => {
            kv("shape", format!("[{}]", shape.iter().map(scalar_str).collect::<Vec<_>>().join(",")))
        }
        Op::Pad { dim, before, after, value } => {
            kv("dim", dim.to_string());
            kv("before", scalar_str(before));
            kv("after", scalar_str(after));
            kv("value", value.to_string());
        }
        Op::Scale { c } | Op::AddScalar { c } => kv("c", c.to_string()),
        Op::ReduceSum { dim, keepdim }
        | Op::ReduceMean { dim, keepdim }
        | Op::ReduceMax { dim, keepdim } => {
            kv("dim", dim.to_string());
            kv("keepdim", keepdim.to_string());
        }
        Op::RmsNorm { eps } | Op::LayerNorm { eps } => kv("eps", eps.to_string()),
        Op::AllReduce { ranks } => kv("ranks", ranks.to_string()),
        Op::AllGather { dim, ranks } => {
            kv("dim", dim.to_string());
            kv("ranks", ranks.to_string());
        }
        Op::ReduceScatter { dim, ranks, index } => {
            kv("dim", dim.to_string());
            kv("ranks", ranks.to_string());
            kv("index", index.to_string());
        }
        Op::Send { chan } | Op::Recv { chan } => kv("chan", chan.to_string()),
        Op::TopK { k } => kv("k", k.to_string()),
        Op::Dispatch { expert, capacity } => {
            kv("expert", expert.to_string());
            kv("capacity", capacity.to_string());
        }
        Op::Combine { experts } => kv("experts", experts.to_string()),
        _ => {}
    }
    s
}

fn scalar_str(s: &crate::symbolic::Scalar) -> String {
    match s.as_const() {
        Some(k) => k.to_string(),
        None => format!("?sym{:?}", s.0.terms),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_running_example() {
        let mut gs = Graph::new("gs");
        let _a = gs.input("A", vec![2, 2]);
        let mut gd = Graph::new("gd");
        let c1 = gd.input("C_1", vec![2, 2]);
        let c2 = gd.input("C_2", vec![2, 2]);
        let namer = Namer { gs: &gs, gd: &gd };
        let e = Expr::op(
            Op::SumN,
            vec![Expr::leaf(TensorRef::d(c1)), Expr::leaf(TensorRef::d(c2))],
        );
        assert_eq!(render(&e, &namer), "sum(C_1, C_2)");
        let e2 = Expr::op(
            Op::Slice { dim: 0, start: 0.into(), end: 2.into() },
            vec![Expr::leaf(TensorRef::d(c1))],
        );
        assert_eq!(render(&e2, &namer), "slice(C_1; dim=0, start=0, end=2)");
    }
}
