//! Textual expression parser — the format users write clean input relations
//! `R_i` in (and the inverse of `expr::print::render`).
//!
//! Grammar:
//! ```text
//! expr  := IDENT | IDENT '(' args? (';' attrs)? ')'
//! args  := expr (',' expr)*
//! attrs := IDENT '=' value (',' IDENT '=' value)*
//! value := INT | FLOAT | BOOL | '[' INT (',' INT)* ']'
//! ```

use super::{Expr, TensorRef};
use crate::ir::{FBits, Op};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<i64>),
}

impl Value {
    fn int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => bail!("expected int attr, got {:?}", self),
        }
    }
    fn float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected float attr"),
        }
    }
    fn usize_(&self) -> Result<usize> {
        Ok(self.int()? as usize)
    }
    fn list(&self) -> Result<&[i64]> {
        match self {
            Value::List(l) => Ok(l),
            _ => bail!("expected list attr"),
        }
    }
    fn bool_(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool attr"),
        }
    }
}

/// Parse an expression; `resolve` maps tensor names to graph tensors.
pub fn parse(text: &str, resolve: &dyn Fn(&str) -> Option<TensorRef>) -> Result<Expr> {
    let mut p = P { b: text.as_bytes(), i: 0, resolve };
    let e = p.expr()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {} of '{}'", p.i, text);
    }
    Ok(e)
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
    resolve: &'a dyn Fn(&str) -> Option<TensorRef>,
}

impl P<'_> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ident(&mut self) -> Result<String> {
        self.ws();
        let start = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || matches!(c, b'_' | b'.' | b':' | b'/')) {
            self.i += 1;
        }
        if self.i == start {
            bail!("expected identifier at byte {}", start);
        }
        Ok(std::str::from_utf8(&self.b[start..self.i]).unwrap().to_string())
    }

    fn expr(&mut self) -> Result<Expr> {
        let name = self.ident()?;
        self.ws();
        if self.peek() != Some(b'(') {
            // bare tensor name
            let t = (self.resolve)(&name).ok_or_else(|| anyhow!("unknown tensor '{name}'"))?;
            return Ok(Expr::Leaf(t));
        }
        self.i += 1; // '('
        let mut args = Vec::new();
        let mut attrs: BTreeMap<String, Value> = BTreeMap::new();
        self.ws();
        if self.peek() != Some(b')') {
            loop {
                self.ws();
                if self.peek() == Some(b';') {
                    break;
                }
                args.push(self.expr()?);
                self.ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b')') | Some(b';') => break,
                    other => bail!("expected ',' ';' or ')', got {:?}", other.map(|c| c as char)),
                }
            }
            if self.peek() == Some(b';') {
                self.i += 1;
                loop {
                    let key = self.ident()?;
                    self.ws();
                    if self.peek() != Some(b'=') {
                        bail!("expected '=' after attr '{key}'");
                    }
                    self.i += 1;
                    attrs.insert(key, self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b')') => break,
                        other => bail!("expected ',' or ')', got {:?}", other.map(|c| c as char)),
                    }
                }
            }
        }
        if self.peek() != Some(b')') {
            bail!("expected ')' at byte {}", self.i);
        }
        self.i += 1;
        build(&name, args, &attrs)
    }

    fn value(&mut self) -> Result<Value> {
        self.ws();
        match self.peek() {
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                loop {
                    self.ws();
                    if self.peek() == Some(b']') {
                        self.i += 1;
                        return Ok(Value::List(items));
                    }
                    items.push(self.number()?.int()?);
                    self.ws();
                    if self.peek() == Some(b',') {
                        self.i += 1;
                    }
                }
            }
            Some(b't') | Some(b'f') => {
                let w = self.ident()?;
                match w.as_str() {
                    "true" => Ok(Value::Bool(true)),
                    "false" => Ok(Value::Bool(false)),
                    other => bail!("bad value '{other}'"),
                }
            }
            _ => self.number(),
        }
    }

    fn number(&mut self) -> Result<Value> {
        self.ws();
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                self.i += 1;
            } else if matches!(c, b'.' | b'e' | b'E' | b'-' | b'+') && self.i > start {
                is_float = is_float || c == b'.' || c == b'e' || c == b'E';
                if matches!(c, b'-' | b'+') && !matches!(self.b.get(self.i - 1), Some(b'e' | b'E')) {
                    break;
                }
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_float {
            Ok(Value::Float(text.parse()?))
        } else {
            Ok(Value::Int(text.parse()?))
        }
    }
}

fn build(name: &str, args: Vec<Expr>, attrs: &BTreeMap<String, Value>) -> Result<Expr> {
    let need = |k: &str| attrs.get(k).ok_or_else(|| anyhow!("op '{name}' needs attr '{k}'"));
    let op = match name {
        "identity" => Op::Identity,
        "slice" => Op::Slice {
            dim: need("dim")?.usize_()?,
            start: need("start")?.int()?.into(),
            end: need("end")?.int()?.into(),
        },
        "concat" => Op::Concat { dim: need("dim")?.usize_()? },
        "transpose" => Op::Transpose {
            perm: need("perm")?.list()?.iter().map(|&i| i as usize).collect(),
        },
        "reshape" => Op::Reshape {
            shape: need("shape")?.list()?.iter().map(|&i| i.into()).collect(),
        },
        "pad" => Op::Pad {
            dim: need("dim")?.usize_()?,
            before: need("before")?.int()?.into(),
            after: need("after")?.int()?.into(),
            value: FBits::new(attrs.get("value").map(|v| v.float()).transpose()?.unwrap_or(0.0)),
        },
        "sum" => Op::SumN,
        "add" => Op::Add,
        "sub" => Op::Sub,
        "mul" => Op::Mul,
        "div" => Op::Div,
        "maximum" => Op::Maximum,
        "neg" => Op::Neg,
        "exp" => Op::Exp,
        "log" => Op::Log,
        "sqrt" => Op::Sqrt,
        "rsqrt" => Op::Rsqrt,
        "square" => Op::Square,
        "tanh" => Op::Tanh,
        "gelu" => Op::Gelu,
        "silu" => Op::Silu,
        "sigmoid" => Op::Sigmoid,
        "relu" => Op::Relu,
        "scale" => Op::Scale { c: FBits::new(need("c")?.float()?) },
        "add_scalar" => Op::AddScalar { c: FBits::new(need("c")?.float()?) },
        "matmul" => Op::MatMul,
        "reduce_sum" => Op::ReduceSum {
            dim: need("dim")?.usize_()?,
            keepdim: attrs.get("keepdim").map(|v| v.bool_()).transpose()?.unwrap_or(false),
        },
        "reduce_mean" => Op::ReduceMean {
            dim: need("dim")?.usize_()?,
            keepdim: attrs.get("keepdim").map(|v| v.bool_()).transpose()?.unwrap_or(false),
        },
        "reduce_max" => Op::ReduceMax {
            dim: need("dim")?.usize_()?,
            keepdim: attrs.get("keepdim").map(|v| v.bool_()).transpose()?.unwrap_or(false),
        },
        "softmax" => Op::Softmax { dim: need("dim")?.usize_()? },
        "rms_norm" => Op::RmsNorm { eps: FBits::new(need("eps")?.float()?) },
        "layer_norm" => Op::LayerNorm { eps: FBits::new(need("eps")?.float()?) },
        "rope" => Op::Rope,
        "embedding" => Op::Embedding,
        "mse_loss" => Op::MseLoss,
        "all_reduce" => Op::AllReduce { ranks: need("ranks")?.usize_()? },
        "all_gather" => Op::AllGather {
            dim: need("dim")?.usize_()?,
            ranks: need("ranks")?.usize_()?,
        },
        "reduce_scatter" => Op::ReduceScatter {
            dim: need("dim")?.usize_()?,
            ranks: need("ranks")?.usize_()?,
            index: need("index")?.usize_()?,
        },
        "send" => Op::Send { chan: need("chan")?.usize_()? },
        "recv" => Op::Recv { chan: need("chan")?.usize_()? },
        "topk" => Op::TopK { k: need("k")?.usize_()? },
        "dispatch" => Op::Dispatch {
            expert: need("expert")?.usize_()?,
            capacity: need("capacity")?.usize_()?,
        },
        "combine" => Op::Combine { experts: need("experts")?.usize_()? },
        custom => Op::Custom { name: custom.to_string() },
    };
    Ok(Expr::Op(op, args))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::print::{render, Namer};
    use crate::ir::Graph;

    fn graphs() -> (Graph, Graph) {
        let mut gs = Graph::new("gs");
        gs.input("A", vec![4, 4]);
        let mut gd = Graph::new("gd");
        gd.input("A_1", vec![4, 2]);
        gd.input("A_2", vec![4, 2]);
        (gs, gd)
    }

    #[test]
    fn parse_concat() {
        let (gs, gd) = graphs();
        let resolve = |n: &str| gd.tensor_by_name(n).map(TensorRef::d);
        let e = parse("concat(A_1, A_2; dim=1)", &resolve).unwrap();
        assert!(e.is_clean());
        let namer = Namer { gs: &gs, gd: &gd };
        assert_eq!(render(&e, &namer), "concat(A_1, A_2; dim=1)");
    }

    #[test]
    fn parse_roundtrips_various() {
        let (gs, gd) = graphs();
        let resolve = |n: &str| gd.tensor_by_name(n).map(TensorRef::d);
        let namer = Namer { gs: &gs, gd: &gd };
        for src in [
            "sum(A_1, A_2)",
            "slice(A_1; dim=0, start=1, end=3)",
            "transpose(A_1; perm=[1,0])",
            "matmul(A_1, A_2)",
            "scale(A_1; c=0.5)",
            "reduce_sum(A_1; dim=0, keepdim=true)",
            "all_gather(A_1, A_2; dim=1, ranks=2)",
            "topk(A_1; k=1)",
            "dispatch(A_1, A_2; expert=1, capacity=4)",
            "combine(A_1, A_2; experts=1)",
        ] {
            let e = parse(src, &resolve).unwrap();
            assert_eq!(render(&e, &namer), src, "roundtrip {src}");
        }
    }

    #[test]
    fn bare_tensor_leaf() {
        let (_, gd) = graphs();
        let resolve = |n: &str| gd.tensor_by_name(n).map(TensorRef::d);
        let e = parse("A_1", &resolve).unwrap();
        assert_eq!(e, Expr::Leaf(TensorRef::d(0)));
    }

    #[test]
    fn unknown_tensor_errors() {
        let (_, gd) = graphs();
        let resolve = |n: &str| gd.tensor_by_name(n).map(TensorRef::d);
        assert!(parse("nope", &resolve).is_err());
        assert!(parse("concat(A_1; dim=9999999999999999999999)", &resolve).is_err());
        assert!(parse("slice(A_1; dim=0)", &resolve).is_err()); // missing attrs
    }

    #[test]
    fn custom_op_parses() {
        let (_, gd) = graphs();
        let resolve = |n: &str| gd.tensor_by_name(n).map(TensorRef::d);
        let e = parse("fused_rms(A_1, A_2)", &resolve).unwrap();
        match e {
            Expr::Op(Op::Custom { ref name }, ref args) => {
                assert_eq!(name, "fused_rms");
                assert_eq!(args.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }
}
