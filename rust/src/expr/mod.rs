//! The expression language ρ (paper §3.2).
//!
//! A relation maps a tensor `t ∈ T(G_s)` to expressions over tensors of
//! `G_d`. Expressions are op trees whose leaves are tensor references; an
//! expression is *clean* when every operator in it merely rearranges
//! elements or combines distributed partial results (`Op::is_clean`).

pub mod eval;
pub mod parse;
pub mod print;

use crate::ir::{Op, TensorId};

/// Which graph a leaf tensor lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Side {
    /// Sequential specification `G_s`.
    S,
    /// Distributed implementation `G_d`.
    D,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorRef {
    pub side: Side,
    pub id: TensorId,
}

impl TensorRef {
    pub fn s(id: TensorId) -> Self {
        TensorRef { side: Side::S, id }
    }
    pub fn d(id: TensorId) -> Self {
        TensorRef { side: Side::D, id }
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Expr {
    Leaf(TensorRef),
    Op(Op, Vec<Expr>),
}

impl Expr {
    pub fn leaf(t: TensorRef) -> Expr {
        Expr::Leaf(t)
    }

    pub fn op(op: Op, args: Vec<Expr>) -> Expr {
        Expr::Op(op, args)
    }

    /// Number of operator applications (the paper's nested-expression count,
    /// used to pick the simplest self-provable representative, §4.3.2).
    pub fn size(&self) -> usize {
        match self {
            Expr::Leaf(_) => 0,
            Expr::Op(_, args) => 1 + args.iter().map(Expr::size).sum::<usize>(),
        }
    }

    /// Is every operator in this expression clean (§3.2)?
    pub fn is_clean(&self) -> bool {
        match self {
            Expr::Leaf(_) => true,
            Expr::Op(op, args) => op.is_clean() && args.iter().all(Expr::is_clean),
        }
    }

    /// Distinct leaf tensors, sorted — the expression's "leaf signature".
    pub fn leaves(&self) -> Vec<TensorRef> {
        let mut out = Vec::new();
        self.collect_leaves(&mut out);
        out.sort();
        out.dedup();
        out
    }

    fn collect_leaves(&self, out: &mut Vec<TensorRef>) {
        match self {
            Expr::Leaf(t) => out.push(*t),
            Expr::Op(_, args) => {
                for a in args {
                    a.collect_leaves(out);
                }
            }
        }
    }

    /// Is this a *conditional* (router-guarded) relation expression — does
    /// it contain a `Dispatch`/`Combine` whose meaning depends on a router
    /// operand? Such expressions are clean only relative to the guard
    /// tensors reported by [`Expr::guard_leaves`].
    pub fn is_router_conditioned(&self) -> bool {
        match self {
            Expr::Leaf(_) => false,
            Expr::Op(op, args) => {
                matches!(op.tag(), crate::ir::OpTag::Dispatch | crate::ir::OpTag::Combine)
                    || args.iter().any(Expr::is_router_conditioned)
            }
        }
    }

    /// The guard tensors of a conditional relation: every leaf reachable
    /// through a *router operand* position — input 1 of `Dispatch`, input 0
    /// of `Combine`. The expression reconstructs its `G_s` tensor only
    /// because these tensors are the routing decision both graphs share;
    /// they are the "router predicate" of the paper-style conditional
    /// relation. Sorted and deduplicated like [`Expr::leaves`].
    pub fn guard_leaves(&self) -> Vec<TensorRef> {
        let mut out = Vec::new();
        self.collect_guard_leaves(false, &mut out);
        out.sort();
        out.dedup();
        out
    }

    fn collect_guard_leaves(&self, in_guard: bool, out: &mut Vec<TensorRef>) {
        match self {
            Expr::Leaf(t) => {
                if in_guard {
                    out.push(*t);
                }
            }
            Expr::Op(op, args) => {
                for (i, a) in args.iter().enumerate() {
                    let guard_pos = match op.tag() {
                        crate::ir::OpTag::Dispatch => i == 1,
                        crate::ir::OpTag::Combine => i == 0,
                        _ => false,
                    };
                    a.collect_guard_leaves(in_guard || guard_pos, out);
                }
            }
        }
    }

    /// Do all leaves satisfy `pred`?
    pub fn leaves_all(&self, pred: &impl Fn(TensorRef) -> bool) -> bool {
        match self {
            Expr::Leaf(t) => pred(*t),
            Expr::Op(_, args) => args.iter().all(|a| a.leaves_all(pred)),
        }
    }

    /// Substitute leaves via `f` (used to splice relations together).
    pub fn substitute(&self, f: &impl Fn(TensorRef) -> Option<Expr>) -> Expr {
        match self {
            Expr::Leaf(t) => f(*t).unwrap_or_else(|| self.clone()),
            Expr::Op(op, args) => {
                Expr::Op(op.clone(), args.iter().map(|a| a.substitute(f)).collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Expr {
        // sum(C1, C2) with C1=matmul(A1,B1)
        Expr::op(
            Op::SumN,
            vec![
                Expr::op(Op::MatMul, vec![Expr::leaf(TensorRef::d(0)), Expr::leaf(TensorRef::d(1))]),
                Expr::leaf(TensorRef::d(2)),
            ],
        )
    }

    #[test]
    fn size_counts_ops() {
        assert_eq!(sample().size(), 2);
        assert_eq!(Expr::leaf(TensorRef::d(0)).size(), 0);
    }

    #[test]
    fn clean_requires_all_ops_clean() {
        assert!(!sample().is_clean()); // contains matmul
        let clean = Expr::op(
            Op::Concat { dim: 0 },
            vec![Expr::leaf(TensorRef::d(0)), Expr::leaf(TensorRef::d(1))],
        );
        assert!(clean.is_clean());
    }

    #[test]
    fn leaves_sorted_dedup() {
        let e = Expr::op(Op::Add, vec![Expr::leaf(TensorRef::d(2)), Expr::leaf(TensorRef::d(2))]);
        assert_eq!(e.leaves(), vec![TensorRef::d(2)]);
    }

    #[test]
    fn router_conditioned_expressions_and_guards() {
        // combine(m, dispatch(x, m; 0), dispatch(x, m; 1)) — clean, but
        // conditional on the router leaf m
        let m = Expr::leaf(TensorRef::d(7));
        let x = Expr::leaf(TensorRef::d(3));
        let d0 = Expr::op(Op::Dispatch { expert: 0, capacity: 4 }, vec![x.clone(), m.clone()]);
        let d1 = Expr::op(Op::Dispatch { expert: 1, capacity: 4 }, vec![x.clone(), m.clone()]);
        let e = Expr::op(Op::Combine { experts: 2 }, vec![m.clone(), d0, d1]);
        assert!(e.is_clean(), "dispatch/combine are (conditionally) clean");
        assert!(e.is_router_conditioned());
        assert_eq!(e.guard_leaves(), vec![TensorRef::d(7)], "the router is the guard");
        // an unconditional clean expression has no guards
        let plain = Expr::op(Op::Concat { dim: 0 }, vec![x.clone(), m]);
        assert!(!plain.is_router_conditioned());
        assert!(plain.guard_leaves().is_empty());
        // topk itself is compute, not a clean rearrangement
        let tk = Expr::op(Op::TopK { k: 1 }, vec![x]);
        assert!(!tk.is_clean());
    }

    #[test]
    fn substitute_splices() {
        let e = Expr::op(Op::Neg, vec![Expr::leaf(TensorRef::s(5))]);
        let out = e.substitute(&|t| {
            (t == TensorRef::s(5)).then(|| Expr::leaf(TensorRef::d(9)))
        });
        assert_eq!(out, Expr::op(Op::Neg, vec![Expr::leaf(TensorRef::d(9))]));
    }
}
