//! Lint findings — the stable output surface of the static analysis.
//!
//! A [`LintFinding`] is a *diagnostic*, never a verdict: findings ride along
//! with whatever the e-graph oracle decides (`EXPERIMENTS.md §Static
//! analysis` states the soundness contract). Codes and the JSON shape are
//! stable so CI gates and downstream tooling can key on them.

use crate::util::json::Json;

/// One static-analysis diagnostic, anchored to a `G_d` node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintFinding {
    /// Stable machine-readable code (e.g. `partial_no_reduce`,
    /// `chan_crossed`). The full vocabulary is listed in
    /// [`crate::analysis`]'s module docs.
    pub code: &'static str,
    /// Name of the `G_d` node the finding anchors to (the locus).
    pub node: String,
    /// One-line human-readable explanation.
    pub detail: String,
}

impl LintFinding {
    pub fn new(code: &'static str, node: impl Into<String>, detail: impl Into<String>) -> Self {
        LintFinding { code, node: node.into(), detail: detail.into() }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("node", Json::str(self.node.clone())),
            ("detail", Json::str(self.detail.clone())),
        ])
    }
}

/// All findings of one `analyze` run, in a canonical order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LintReport {
    pub findings: Vec<LintFinding>,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Canonicalize: sort by (node, code, detail) and drop exact duplicates,
    /// so the report is a pure function of the graph — independent of
    /// traversal order. CI diffing depends on this.
    pub fn normalize(&mut self) {
        self.findings.sort_by(|a, b| {
            (a.node.as_str(), a.code, a.detail.as_str())
                .cmp(&(b.node.as_str(), b.code, b.detail.as_str()))
        });
        self.findings.dedup();
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.findings.len() as f64)),
            ("findings", Json::Arr(self.findings.iter().map(LintFinding::to_json).collect())),
        ])
    }

    /// Plain-text rendering for the CLI (one line per finding).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        if self.findings.is_empty() {
            out.push_str("lint: clean (0 findings)\n");
            return out;
        }
        let _ = writeln!(out, "lint: {} finding(s)", self.findings.len());
        for f in &self.findings {
            let _ = writeln!(out, "  [{}] at '{}': {}", f.code, f.node, f.detail);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_sorts_and_dedups() {
        let mut r = LintReport {
            findings: vec![
                LintFinding::new("b_code", "n2", "y"),
                LintFinding::new("a_code", "n1", "x"),
                LintFinding::new("a_code", "n1", "x"),
            ],
        };
        r.normalize();
        assert_eq!(r.findings.len(), 2);
        assert_eq!(r.findings[0].node, "n1");
        assert_eq!(r.findings[1].node, "n2");
    }

    #[test]
    fn json_shape_is_stable() {
        let r = LintReport { findings: vec![LintFinding::new("c", "n", "d")] };
        let j = r.to_json();
        assert_eq!(j.get("count").as_usize(), Some(1));
        let arr = j.get("findings").as_arr().unwrap();
        assert_eq!(arr[0].get("code").as_str(), Some("c"));
        assert_eq!(arr[0].get("node").as_str(), Some("n"));
    }
}
