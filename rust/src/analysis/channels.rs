//! Channel-wiring and liveness lints over the `Send`/`Recv` graph.
//!
//! Pipeline lowerings allocate one channel per (stage boundary,
//! micro-batch) — or, for buffer-pool schedules, per (boundary, slot,
//! epoch) via [`crate::schedule::buffer_tag`]. These lints check the wiring
//! is a well-formed matching:
//!
//! - `chan_crossed` — a `Recv` wired to a `Send` on a different channel;
//! - `recv_unmatched` — a `Recv` whose input is not a `Send` output at all;
//! - `send_orphan` — a `Send` whose value no `Recv` ever consumes;
//! - `chan_duplicate` — one channel id carrying two sends or two recvs;
//! - `buffer_epoch_gap` — a buffer slot whose send epochs are not the
//!   contiguous run `0..n` the schedule lowering emits;
//! - `stage_cycle` — the stage graph (nodes contracted over all non-
//!   boundary edges) has a cycle: every schedule would deadlock on it.

use super::report::LintFinding;
use crate::ir::{Graph, Op};
use rustc_hash::{FxHashMap, FxHashSet};

/// Run all channel lints, appending findings.
pub fn check(g: &Graph, findings: &mut Vec<LintFinding>) {
    let mut sends: FxHashMap<usize, Vec<usize>> = FxHashMap::default(); // chan -> node ids
    let mut recvs: FxHashMap<usize, Vec<usize>> = FxHashMap::default();
    for nid in g.topo_order() {
        let node = g.node(nid);
        match node.op {
            Op::Send { chan } => sends.entry(chan).or_default().push(nid as usize),
            Op::Recv { chan } => recvs.entry(chan).or_default().push(nid as usize),
            _ => {}
        }
    }
    if sends.is_empty() && recvs.is_empty() {
        return;
    }

    // ---- per-recv: the producer must be the matching send ----
    for ids in recvs.values() {
        for &rid in ids {
            let rnode = g.node(rid as u32);
            let Op::Recv { chan } = rnode.op else { continue };
            match g.producer(rnode.inputs[0]) {
                Some(p) => match p.op {
                    Op::Send { chan: sc } if sc == chan => {}
                    Op::Send { chan: sc } => findings.push(LintFinding::new(
                        "chan_crossed",
                        rnode.name.clone(),
                        format!(
                            "recv on channel {chan} is wired to send '{}' on channel {sc}",
                            p.name
                        ),
                    )),
                    _ => findings.push(LintFinding::new(
                        "recv_unmatched",
                        rnode.name.clone(),
                        format!(
                            "recv on channel {chan} reads '{}', which is not a send output",
                            p.name
                        ),
                    )),
                },
                None => findings.push(LintFinding::new(
                    "recv_unmatched",
                    rnode.name.clone(),
                    format!(
                        "recv on channel {chan} reads graph input '{}' — the stage \
                         boundary transfer was dropped",
                        g.tensor(rnode.inputs[0]).name
                    ),
                )),
            }
        }
    }

    // ---- per-send: somebody must receive the value ----
    for ids in sends.values() {
        for &sid in ids {
            let snode = g.node(sid as u32);
            let received = g
                .consumers(snode.output)
                .iter()
                .any(|&c| matches!(g.node(c).op, Op::Recv { .. }));
            if !received && !g.is_output(snode.output) {
                findings.push(LintFinding::new(
                    "send_orphan",
                    snode.name.clone(),
                    "send value is never received by any recv".to_string(),
                ));
            }
        }
    }

    // ---- duplicate channel ids ----
    for (chan, ids) in sends.iter().chain(recvs.iter()) {
        for &nid in &ids[1..] {
            findings.push(LintFinding::new(
                "chan_duplicate",
                g.node(nid as u32).name.clone(),
                format!("channel {chan} already carries '{}'", g.node(ids[0] as u32).name),
            ));
        }
    }

    // ---- buffer-pool epoch discipline (schedule-lowered graphs only) ----
    let mut slots: FxHashMap<(usize, usize), Vec<(usize, usize)>> = FxHashMap::default();
    for ids in sends.values() {
        for &sid in ids {
            let Op::Send { chan } = g.node(sid as u32).op else { continue };
            if let Some((boundary, slot, epoch)) = crate::schedule::decode_buffer_tag(chan) {
                slots.entry((boundary, slot)).or_default().push((epoch, sid));
            }
        }
    }
    for ((boundary, slot), mut uses) in slots {
        uses.sort_unstable();
        let contiguous =
            uses.iter().enumerate().all(|(i, &(epoch, _))| epoch == i);
        if !contiguous {
            // deterministic locus: the send with the smallest name
            let node = uses
                .iter()
                .map(|&(_, sid)| &g.node(sid as u32).name)
                .min()
                .expect("slot group is non-empty");
            findings.push(LintFinding::new(
                "buffer_epoch_gap",
                node.clone(),
                format!(
                    "buffer {slot} at boundary {boundary} is written in epochs {:?}; \
                     expected the contiguous run 0..{}",
                    uses.iter().map(|&(e, _)| e).collect::<Vec<_>>(),
                    uses.len()
                ),
            ));
        }
    }

    // ---- stage-graph cycle = communication deadlock ----
    check_stage_cycle(g, findings);
}

/// Contract the graph over every edge *except* send→recv boundaries; the
/// resulting components are the pipeline stages. A cycle among stages means
/// every rank would wait on a value transitively derived from its own
/// output — a deadlock under any schedule.
fn check_stage_cycle(g: &Graph, findings: &mut Vec<LintFinding>) {
    let n = g.num_nodes();
    let mut uf: Vec<usize> = (0..n).collect();
    fn find(uf: &mut [usize], mut x: usize) -> usize {
        while uf[x] != x {
            uf[x] = uf[uf[x]];
            x = uf[x];
        }
        x
    }
    // union along all intra-stage edges
    let mut boundary: Vec<(usize, usize)> = Vec::new(); // (send node, recv node)
    for nid in g.topo_order() {
        let node = g.node(nid);
        for &t in &node.inputs {
            let Some(p) = g.producer(t) else { continue };
            let pid = g
                .tensor(t)
                .producer
                .expect("producer() and tensor.producer agree") as usize;
            let is_boundary = matches!(p.op, Op::Send { .. }) && matches!(node.op, Op::Recv { .. });
            if is_boundary {
                boundary.push((pid, nid as usize));
            } else {
                let (a, b) = (find(&mut uf, pid), find(&mut uf, nid as usize));
                if a != b {
                    uf[a] = b;
                }
            }
        }
    }
    if boundary.is_empty() {
        return;
    }
    // directed component graph over the boundary edges
    let mut edges: FxHashSet<(usize, usize)> = FxHashSet::default();
    for &(s, r) in &boundary {
        edges.insert((find(&mut uf, s), find(&mut uf, r)));
    }
    let mut indeg: FxHashMap<usize, usize> = FxHashMap::default();
    let mut adj: FxHashMap<usize, Vec<usize>> = FxHashMap::default();
    let mut comps: FxHashSet<usize> = FxHashSet::default();
    for &(a, b) in &edges {
        comps.insert(a);
        comps.insert(b);
        adj.entry(a).or_default().push(b);
        *indeg.entry(b).or_insert(0) += 1;
    }
    // Kahn's algorithm
    let mut queue: Vec<usize> =
        comps.iter().copied().filter(|c| !indeg.contains_key(c)).collect();
    let mut done: FxHashSet<usize> = FxHashSet::default();
    while let Some(c) = queue.pop() {
        done.insert(c);
        if let Some(next) = adj.get(&c) {
            for &b in next {
                let d = indeg.get_mut(&b).expect("edge target has an indegree entry");
                *d -= 1;
                if *d == 0 {
                    queue.push(b);
                }
            }
        }
    }
    if done.len() == comps.len() {
        return;
    }
    // cycle: anchor the finding at the smallest-named recv in a stuck stage
    let stuck: FxHashSet<usize> = comps.difference(&done).copied().collect();
    let locus = boundary
        .iter()
        .filter(|&&(_, r)| stuck.contains(&find(&mut uf, r)))
        .map(|&(_, r)| &g.node(r as u32).name)
        .min();
    if let Some(name) = locus {
        findings.push(LintFinding::new(
            "stage_cycle",
            name.clone(),
            format!(
                "stage graph has a cycle through {} of {} stages: the receiving \
                 stage transitively feeds its own sender — a communication deadlock \
                 under any schedule",
                stuck.len(),
                comps.len()
            ),
        ));
    }
}
