//! The distribution lattice and its seeding from the iterative relation `R_i`.
//!
//! A [`Fact`] abstracts *how a `G_d` tensor decomposes relative to the
//! sequential value it corresponds to*:
//!
//! - `Replicated` — the full value, deterministically identical everywhere;
//! - `Sharded{dim, ranks, index, ..}` — the `index`-th of `ranks` equal
//!   chunks along `dim`;
//! - `Partial{ranks}` — one of `ranks` addends whose sum is the full value;
//! - `Unknown` — top: no claim (always sound).
//!
//! Two refinements keep the analysis false-alarm-free on clean graphs:
//!
//! - `of` records *which* full value a shard is a chunk of
//!   ([`ShardOf::Gs`] = a sequential tensor named by `R_i`, [`ShardOf::Dt`]
//!   = a `G_d` tensor sliced locally, [`ShardOf::Anon`] = untracked). Order
//!   and mixed-source checks only fire when provenances *definitely*
//!   disagree.
//! - `dist` distinguishes chunks produced by the distribution itself
//!   (seeded per-rank inputs, `ReduceScatter` outputs) from local slices of
//!   replicated data (e.g. rotate-half `Slice`s). Re-gather discipline is
//!   only enforced on `dist: true` shards — a local slice re-concatenated
//!   in any order is the model's own business.

use crate::expr::{Expr, Side, TensorRef};
use crate::ir::{Graph, Op, TensorId};
use crate::relation::Relation;
use rustc_hash::FxHashMap;

/// Which full value a [`Fact::Sharded`] is a chunk of.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardOf {
    /// Chunk of the sequential (`G_s`) tensor with this id, per `R_i`.
    Gs(TensorId),
    /// Chunk of the distributed (`G_d`) tensor with this id (local slice).
    Dt(TensorId),
    /// Provenance not tracked (result of arithmetic on a shard).
    Anon,
}

impl ShardOf {
    /// True only when both sides *definitely* name different sources.
    /// `Anon` never conflicts; neither do a `Gs` and a `Dt` (a local slice
    /// of a replicated copy of `t` is bit-identical to the seeded shard).
    pub fn conflicts(self, other: ShardOf) -> bool {
        match (self, other) {
            (ShardOf::Gs(a), ShardOf::Gs(b)) => a != b,
            (ShardOf::Dt(a), ShardOf::Dt(b)) => a != b,
            _ => false,
        }
    }
}

/// Per-tensor placement fact — the abstract domain of the dataflow pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fact {
    /// Top: nothing is claimed. The default, and the join of any conflict.
    Unknown,
    /// The full sequential-corresponding value, identical on every path.
    Replicated,
    /// The `index`-th of `ranks` equal chunks along `dim`. `dist` marks
    /// collective provenance (seeded per-rank input or `ReduceScatter`
    /// output) as opposed to a local slice of replicated data.
    Sharded { dim: usize, ranks: usize, index: usize, of: ShardOf, dist: bool },
    /// One of `ranks` addends; the full value is their elementwise sum.
    Partial { ranks: usize },
}

impl Fact {
    pub fn is_unknown(self) -> bool {
        matches!(self, Fact::Unknown)
    }

    /// Lattice join: equal facts (ignoring shard provenance tags) stay,
    /// anything else goes to `Unknown`.
    pub fn join(self, other: Fact) -> Fact {
        match (self, other) {
            (Fact::Replicated, Fact::Replicated) => Fact::Replicated,
            (Fact::Partial { ranks: a }, Fact::Partial { ranks: b }) if a == b => {
                Fact::Partial { ranks: a }
            }
            (
                Fact::Sharded { dim: da, ranks: ra, index: ia, of: oa, dist: qa },
                Fact::Sharded { dim: db, ranks: rb, index: ib, of: ob, dist: qb },
            ) if da == db && ra == rb && ia == ib => Fact::Sharded {
                dim: da,
                ranks: ra,
                index: ia,
                of: if oa == ob { oa } else { ShardOf::Anon },
                dist: qa && qb,
            },
            _ => Fact::Unknown,
        }
    }

    /// Short human-readable form for finding details.
    pub fn describe(self) -> String {
        match self {
            Fact::Unknown => "unknown".into(),
            Fact::Replicated => "replicated".into(),
            Fact::Sharded { dim, ranks, index, .. } => {
                format!("shard {index}/{ranks} along dim {dim}")
            }
            Fact::Partial { ranks } => format!("partial sum (1 of {ranks} addends)"),
        }
    }
}

/// Derive seed facts for `G_d` *input* tensors from the relation `R_i`.
///
/// Only the syntactic shapes `RiBuilder` emits are recognized; anything
/// else (router-conditioned MoE candidates, composite expressions) is
/// skipped — seeds may be missing but never wrong. Conflicting seeds for
/// the same `G_d` tensor join to `Unknown`.
pub fn seed_facts(gd: &Graph, ri: &Relation) -> FxHashMap<TensorId, Fact> {
    let mut seeds: FxHashMap<TensorId, Fact> = FxHashMap::default();
    let mut put = |seeds: &mut FxHashMap<TensorId, Fact>, id: TensorId, f: Fact| {
        let merged = match seeds.get(&id) {
            Some(prev) => prev.join(f),
            None => f,
        };
        seeds.insert(id, merged);
    };

    for t in ri.tensors() {
        for cand in ri.get(t) {
            match &cand.expr {
                // `x` — the G_d tensor holds the full sequential value.
                Expr::Leaf(TensorRef { side: Side::D, id }) => {
                    put(&mut seeds, *id, Fact::Replicated);
                }
                Expr::Op(op, args) if args.len() >= 2 => {
                    let leaves: Option<Vec<TensorId>> = args
                        .iter()
                        .map(|a| match a {
                            Expr::Leaf(TensorRef { side: Side::D, id }) => Some(*id),
                            _ => None,
                        })
                        .collect();
                    let Some(leaves) = leaves else { continue };
                    let ranks = leaves.len();
                    match op {
                        // `concat(x_r0, .., x_rk; dim)` / all_gather — each
                        // leaf is one distribution-produced chunk of `t`.
                        Op::Concat { dim } | Op::AllGather { dim, .. } => {
                            for (i, id) in leaves.iter().enumerate() {
                                put(
                                    &mut seeds,
                                    *id,
                                    Fact::Sharded {
                                        dim: *dim,
                                        ranks,
                                        index: i,
                                        of: ShardOf::Gs(t),
                                        dist: true,
                                    },
                                );
                            }
                        }
                        // `sum(x_r0, .., x_rk)` — each leaf is an addend.
                        Op::SumN => {
                            for id in &leaves {
                                put(&mut seeds, *id, Fact::Partial { ranks });
                            }
                        }
                        _ => {}
                    }
                }
                _ => {}
            }
        }
    }

    // Seeds describe graph inputs; a produced tensor that happens to appear
    // in R_i gets its fact from the transfer pass, not from here.
    seeds.retain(|&id, _| (id as usize) < gd.num_tensors() && gd.producer(id).is_none());
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_is_conservative() {
        let s = Fact::Sharded { dim: 0, ranks: 2, index: 1, of: ShardOf::Anon, dist: true };
        assert_eq!(s.join(s), s);
        assert_eq!(s.join(Fact::Replicated), Fact::Unknown);
        assert_eq!(Fact::Partial { ranks: 2 }.join(Fact::Partial { ranks: 4 }), Fact::Unknown);
        assert_eq!(Fact::Replicated.join(Fact::Replicated), Fact::Replicated);
    }

    #[test]
    fn join_demotes_conflicting_provenance_not_the_shard() {
        let a = Fact::Sharded { dim: 0, ranks: 2, index: 0, of: ShardOf::Gs(1), dist: true };
        let b = Fact::Sharded { dim: 0, ranks: 2, index: 0, of: ShardOf::Gs(2), dist: false };
        assert_eq!(
            a.join(b),
            Fact::Sharded { dim: 0, ranks: 2, index: 0, of: ShardOf::Anon, dist: false }
        );
    }

    #[test]
    fn shard_of_conflicts_only_same_kind() {
        assert!(ShardOf::Gs(1).conflicts(ShardOf::Gs(2)));
        assert!(!ShardOf::Gs(1).conflicts(ShardOf::Gs(1)));
        assert!(!ShardOf::Gs(1).conflicts(ShardOf::Dt(2)));
        assert!(!ShardOf::Anon.conflicts(ShardOf::Gs(1)));
    }
}
