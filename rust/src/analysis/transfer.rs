//! Per-op transfer functions: one linear pass over `G_d` in topological
//! order, propagating [`Fact`]s and emitting findings on *definite*
//! contradictions.
//!
//! The pass is deliberately one-sided: whenever an op's behaviour on a fact
//! is not exactly characterized (nonlinear op on a partial sum that autodiff
//! may legitimately compose, slice along a sharded dim, mixed placements),
//! the output goes to `Unknown` *silently*. A finding is emitted only for
//! op/fact combinations that cannot appear in a correct lowering:
//!
//! - `partial_no_reduce` — an unreduced partial sum flowing into an
//!   activation, `Softmax`, a norm, or the loss (nonlinear in a way no
//!   correct strategy defers reduction across);
//! - `softmax_shard_axis` / `norm_shard_axis` — normalizing along an axis
//!   that a collective actually split;
//! - `gather_order` / `gather_mixed_source` / `gather_dim_mismatch` /
//!   `scatter_over_shards` / `elementwise_shard_mismatch` — re-gather
//!   discipline, enforced only on `dist: true` shards;
//! - `collective_arity` — collective `ranks` attr ≠ its input count;
//! - `dispatch_capacity` / `combine_expert_mismatch` /
//!   `combine_gate_unnormalized` — MoE routing structure.

use super::placement::{Fact, ShardOf};
use super::report::LintFinding;
use crate::ir::{Graph, Node, Op, OpTag, TensorId};
use rustc_hash::FxHashMap;

/// Max producer-chain length the MoE structural traces will walk.
const TRACE_DEPTH: usize = 64;

/// Run the dataflow pass. Returns the per-tensor fact table (indexed by
/// `TensorId`) and appends findings.
pub fn propagate(
    gd: &Graph,
    seeds: &FxHashMap<TensorId, Fact>,
    findings: &mut Vec<LintFinding>,
) -> Vec<Fact> {
    let mut facts = vec![Fact::Unknown; gd.num_tensors()];
    for (id, f) in facts.iter_mut().enumerate() {
        let id = id as TensorId;
        if gd.producer(id).is_none() {
            if let Some(&seed) = seeds.get(&id) {
                *f = seed;
            }
        }
    }
    for nid in gd.topo_order() {
        let node = gd.node(nid);
        let fs: Vec<Fact> = node.inputs.iter().map(|&t| facts[t as usize]).collect();
        facts[node.output as usize] = transfer(gd, node, &fs, findings);
    }
    facts
}

/// Strip shard provenance (arithmetic on a chunk yields a chunk of
/// *something else*); other facts pass through.
fn anon(f: Fact) -> Fact {
    match f {
        Fact::Sharded { dim, ranks, index, dist, .. } => {
            Fact::Sharded { dim, ranks, index, of: ShardOf::Anon, dist }
        }
        other => other,
    }
}

fn flag(findings: &mut Vec<LintFinding>, code: &'static str, node: &Node, detail: String) {
    findings.push(LintFinding::new(code, node.name.clone(), detail));
}

fn partial_no_reduce(findings: &mut Vec<LintFinding>, node: &Node, ranks: usize) -> Fact {
    flag(
        findings,
        "partial_no_reduce",
        node,
        format!(
            "nonlinear op {:?} consumes an unreduced partial sum (1 of {ranks} addends); \
             a reduction (AllReduce/SumN/ReduceScatter) is required first",
            node.op.tag()
        ),
    );
    Fact::Unknown
}

/// Shared rule for the five binary elementwise ops once the
/// replicated/partial special cases are exhausted.
fn binary_shard_pair(g: &Graph, node: &Node, a: Fact, b: Fact, out: &mut Vec<LintFinding>) -> Fact {
    match (a, b) {
        (
            Fact::Sharded { dim: da, ranks: ra, index: ia, of: oa, dist: qa },
            Fact::Sharded { dim: db, ranks: rb, index: ib, of: ob, dist: qb },
        ) => {
            if g.shape(node.inputs[0]) != g.shape(node.inputs[1]) {
                return Fact::Unknown;
            }
            if da == db && ra == rb && ia == ib {
                let of = if oa == ob { oa } else { ShardOf::Anon };
                Fact::Sharded { dim: da, ranks: ra, index: ia, of, dist: qa || qb }
            } else if ra == rb && qa && qb {
                flag(
                    out,
                    "elementwise_shard_mismatch",
                    node,
                    format!(
                        "elementwise {:?} combines misaligned shards: lhs is shard {ia}/{ra} \
                         along dim {da}, rhs is shard {ib}/{rb} along dim {db}",
                        node.op.tag()
                    ),
                );
                Fact::Unknown
            } else {
                Fact::Unknown
            }
        }
        // shard ⊕ replicated = the same chunk of (full ⊕ full): valid for
        // all five ops because the replicated side corresponds elementwise.
        (s @ Fact::Sharded { .. }, Fact::Replicated)
        | (Fact::Replicated, s @ Fact::Sharded { .. }) => anon(s),
        _ => Fact::Unknown,
    }
}

/// Shared gather rule for `AllGather` and `Concat`: all-replicated inputs
/// reassemble to a replicated value; a full set of collective-provenance
/// shards must be gathered along the shard dim, from one source, in rank
/// order. Anything less than definite stays silent.
fn check_gather(node: &Node, dim: usize, fs: &[Fact], out: &mut Vec<LintFinding>) -> Fact {
    if !fs.is_empty() && fs.iter().all(|f| matches!(f, Fact::Replicated)) {
        return Fact::Replicated;
    }
    let mut shards = Vec::with_capacity(fs.len());
    for f in fs {
        match *f {
            Fact::Sharded { dim: sdim, ranks, index, of, dist: true } if ranks == fs.len() => {
                shards.push((sdim, ranks, index, of));
            }
            _ => return Fact::Unknown,
        }
    }
    let sd = shards[0].0;
    if shards.iter().any(|s| s.0 != sd) {
        return Fact::Unknown;
    }
    if sd != dim {
        flag(
            out,
            "gather_dim_mismatch",
            node,
            format!("gathers along dim {dim} but inputs are sharded along dim {sd}"),
        );
        return Fact::Unknown;
    }
    let mut bad = false;
    for (i, si) in shards.iter().enumerate() {
        for sj in shards.iter().skip(i + 1) {
            if si.3.conflicts(sj.3) {
                flag(
                    out,
                    "gather_mixed_source",
                    node,
                    "gather mixes shards of two different source tensors".to_string(),
                );
                bad = true;
            }
        }
        if bad {
            break;
        }
    }
    for (j, s) in shards.iter().enumerate() {
        if s.2 != j {
            flag(
                out,
                "gather_order",
                node,
                format!("operand {j} holds shard index {} (expected {j}): shards are \
                         duplicated or out of rank order", s.2),
            );
            bad = true;
            break;
        }
    }
    if bad {
        Fact::Unknown
    } else {
        Fact::Replicated
    }
}

/// Walk producers through unary-elementwise ops / matmul-lhs / send / recv
/// to the `Dispatch` feeding an expert output, if one is syntactically
/// reachable.
fn trace_to_dispatch(g: &Graph, mut t: TensorId) -> Option<&Node> {
    for _ in 0..TRACE_DEPTH {
        let n = g.producer(t)?;
        match &n.op {
            Op::Dispatch { .. } => return Some(n),
            Op::MatMul => t = n.inputs[0],
            Op::Send { .. } | Op::Recv { .. } => t = n.inputs[0],
            op if op.is_unary_elementwise() => t = n.inputs[0],
            _ => return None,
        }
    }
    None
}

/// Column offset of a combine's gate matrix: per-rank lowerings slice the
/// `[rows, E]` gate tensor along dim 1, so expert slot `j` locally is
/// global expert `offset + j`.
fn gate_col_offset(g: &Graph, mut t: TensorId) -> usize {
    for _ in 0..TRACE_DEPTH {
        let Some(n) = g.producer(t) else { return 0 };
        match &n.op {
            Op::Slice { dim: 1, start, .. } => {
                return start.as_const().map(|v| v.max(0) as usize).unwrap_or(0)
            }
            op if op.is_unary_elementwise() => t = n.inputs[0],
            _ => return 0,
        }
    }
    0
}

/// The node that actually *computes* a combine's gate weights, looking
/// through slices and unary elementwise ops. `None` when the chain ends at
/// a graph input (nothing to check).
fn gate_landing(g: &Graph, mut t: TensorId) -> Option<&Node> {
    for _ in 0..TRACE_DEPTH {
        let n = g.producer(t)?;
        match &n.op {
            Op::Slice { .. } => t = n.inputs[0],
            op if op.is_unary_elementwise() => t = n.inputs[0],
            _ => return Some(n),
        }
    }
    None
}

fn check_combine(g: &Graph, node: &Node, experts: usize, out: &mut Vec<LintFinding>) {
    // (1) each expert slot must be fed by the dispatch for that expert.
    let offset = gate_col_offset(g, node.inputs[0]);
    for j in 0..experts {
        let Some(&yt) = node.inputs.get(1 + j) else { break };
        if let Some(disp) = trace_to_dispatch(g, yt) {
            if let Op::Dispatch { expert, .. } = disp.op {
                if expert != offset + j {
                    out.push(LintFinding::new(
                        "combine_expert_mismatch",
                        disp.name.clone(),
                        format!(
                            "combine '{}' slot {j} (global expert {}) is fed by the \
                             dispatch for expert {expert}",
                            node.name,
                            offset + j
                        ),
                    ));
                }
            }
        }
    }
    // (2) gate weights must come from a per-row normalization (Div).
    if let Some(landing) = gate_landing(g, node.inputs[0]) {
        if landing.op.tag() != OpTag::Div {
            flag(
                out,
                "combine_gate_unnormalized",
                node,
                format!(
                    "gate weights come from {:?} node '{}', not a per-row \
                     normalizing Div",
                    landing.op.tag(),
                    landing.name
                ),
            );
        }
    }
}

/// The per-op transfer function.
fn transfer(g: &Graph, node: &Node, fs: &[Fact], out: &mut Vec<LintFinding>) -> Fact {
    use Fact::{Partial, Replicated, Sharded, Unknown};
    match &node.op {
        // ---- placement-preserving ----
        Op::Identity | Op::Send { .. } | Op::Recv { .. } => fs[0],

        // ---- linear unaries: every fact survives ----
        Op::Neg | Op::Scale { .. } => anon(fs[0]),

        // affine, not linear: shifts each addend, so Partial is lost
        // (silently — autodiff composes these freely on non-partial data).
        Op::AddScalar { .. } => match fs[0] {
            Replicated => Replicated,
            s @ Sharded { .. } => anon(s),
            _ => Unknown,
        },

        // ---- nonlinear math primitives: no flag on Partial (backward
        // graphs apply these to forward activations; a partial sum reaching
        // one is handled, if ever observable, by the e-graph oracle) ----
        Op::Exp | Op::Log | Op::Sqrt | Op::Rsqrt | Op::Square => match fs[0] {
            Replicated => Replicated,
            s @ Sharded { .. } => anon(s),
            _ => Unknown,
        },

        // ---- activations: a partial sum here is definitely wrong ----
        Op::Tanh | Op::Gelu | Op::Silu | Op::Sigmoid | Op::Relu => match fs[0] {
            Partial { ranks } => partial_no_reduce(out, node, ranks),
            Replicated => Replicated,
            s @ Sharded { .. } => anon(s),
            Unknown => Unknown,
        },

        // ---- binary elementwise ----
        Op::Add | Op::Sub => match (fs[0], fs[1]) {
            (Replicated, Replicated) => Replicated,
            (Partial { ranks: a }, Partial { ranks: b }) if a == b => Partial { ranks: a },
            (a, b) => binary_shard_pair(g, node, a, b, out),
        },
        Op::Mul => match (fs[0], fs[1]) {
            (Replicated, Replicated) => Replicated,
            (Partial { ranks }, Replicated) | (Replicated, Partial { ranks }) => {
                Partial { ranks }
            }
            (Partial { .. }, Partial { .. }) => Unknown,
            (a, b) => binary_shard_pair(g, node, a, b, out),
        },
        Op::Div => match (fs[0], fs[1]) {
            (Replicated, Replicated) => Replicated,
            (Partial { ranks }, Replicated) => Partial { ranks },
            (a @ Sharded { .. }, b) | (a, b @ Sharded { .. }) => {
                binary_shard_pair(g, node, a, b, out)
            }
            _ => Unknown,
        },
        Op::Maximum => match (fs[0], fs[1]) {
            (Replicated, Replicated) => Replicated,
            (Partial { .. }, _) | (_, Partial { .. }) => Unknown,
            (a, b) => binary_shard_pair(g, node, a, b, out),
        },

        // ---- matmul: the contraction is where partial sums are born ----
        Op::MatMul => {
            let ar = g.shape(node.inputs[0]).len();
            let br = g.shape(node.inputs[1]).len();
            let or = g.shape(node.output).len();
            match (fs[0], fs[1]) {
                (Replicated, Replicated) => Replicated,
                (Partial { ranks }, Replicated) | (Replicated, Partial { ranks }) => {
                    Partial { ranks }
                }
                (Sharded { dim, ranks, index, dist, .. }, Replicated) => {
                    if dim + 1 == ar {
                        Unknown // contraction dim sharded vs full rhs
                    } else if dim + 2 == ar {
                        Sharded { dim: or - 2, ranks, index, of: ShardOf::Anon, dist }
                    } else if br <= ar {
                        // batch dim of lhs only: rhs broadcasts across it
                        Sharded { dim, ranks, index, of: ShardOf::Anon, dist }
                    } else {
                        Unknown
                    }
                }
                (Replicated, Sharded { dim, ranks, index, dist, .. }) => {
                    if dim + 1 == br {
                        Sharded { dim: or - 1, ranks, index, of: ShardOf::Anon, dist }
                    } else {
                        Unknown
                    }
                }
                (
                    Sharded { dim: da, ranks: ra, index: ia, of: oa, dist: qa },
                    Sharded { dim: db, ranks: rb, index: ib, of: ob, dist: qb },
                ) => {
                    // k-sharded × k-sharded with matching chunks: each rank
                    // computes one addend of the full product.
                    if da + 1 == ar
                        && db + 2 == br
                        && ra == rb
                        && ia == ib
                        && (qa || qb)
                        && !oa.conflicts(ob)
                    {
                        Partial { ranks: ra }
                    } else {
                        Unknown
                    }
                }
                _ => Unknown,
            }
        }

        // ---- structural ----
        Op::Transpose { perm } => match fs[0] {
            Replicated => Replicated,
            p @ Partial { .. } => p,
            Sharded { dim, ranks, index, dist, .. } => {
                match perm.iter().position(|&p| p == dim) {
                    Some(nd) => Sharded { dim: nd, ranks, index, of: ShardOf::Anon, dist },
                    None => Unknown,
                }
            }
            Unknown => Unknown,
        },
        Op::Reshape { .. } => match fs[0] {
            Replicated => Replicated,
            p @ Partial { .. } => p,
            _ => Unknown,
        },
        Op::Pad { .. } => match fs[0] {
            Replicated => Replicated,
            _ => Unknown,
        },
        Op::Slice { dim, start, end } => match fs[0] {
            Replicated => {
                // An aligned 1/k slice of a replicated tensor is a local
                // chunk (dist: false — no re-gather discipline applies);
                // any other slice of a replicated value is still
                // deterministic-everywhere, which is all `Replicated`
                // promises to the checks.
                if let (Some(lo), Some(hi)) = (start.as_const(), end.as_const()) {
                    let total = g.shape(node.inputs[0])[*dim];
                    let w = hi - lo;
                    if w > 0 && w < total && lo >= 0 && total % w == 0 && lo % w == 0 {
                        return Sharded {
                            dim: *dim,
                            ranks: (total / w) as usize,
                            index: (lo / w) as usize,
                            of: ShardOf::Dt(node.inputs[0]),
                            dist: false,
                        };
                    }
                }
                Replicated
            }
            s @ Sharded { dim: sd, .. } if sd != *dim => anon(s),
            p @ Partial { .. } => p,
            _ => Unknown,
        },

        // ---- reductions ----
        Op::ReduceSum { dim, keepdim } | Op::ReduceMean { dim, keepdim } => match fs[0] {
            Replicated => Replicated,
            p @ Partial { .. } => p, // linear: reduce each addend, then sum
            Sharded { dim: sd, ranks, index, dist, .. } if sd != *dim => {
                let nd = if !keepdim && *dim < sd { sd - 1 } else { sd };
                Sharded { dim: nd, ranks, index, of: ShardOf::Anon, dist }
            }
            _ => Unknown,
        },
        Op::ReduceMax { dim, keepdim } => match fs[0] {
            Replicated => Replicated,
            Sharded { dim: sd, ranks, index, dist, .. } if sd != *dim => {
                let nd = if !keepdim && *dim < sd { sd - 1 } else { sd };
                Sharded { dim: nd, ranks, index, of: ShardOf::Anon, dist }
            }
            _ => Unknown,
        },

        // ---- normalizers: flag partial sums and split normalization axes ----
        Op::Softmax { dim } => match fs[0] {
            Replicated => Replicated,
            Partial { ranks } => partial_no_reduce(out, node, ranks),
            Sharded { dim: sd, ranks, dist: true, .. } if sd == *dim => {
                flag(
                    out,
                    "softmax_shard_axis",
                    node,
                    format!(
                        "softmax normalizes dim {dim}, but the input is split into \
                         {ranks} shards along that dim — each rank normalizes over \
                         a fraction of the row"
                    ),
                );
                Unknown
            }
            s @ Sharded { dim: sd, .. } if sd != *dim => anon(s),
            _ => Unknown,
        },
        Op::RmsNorm { .. } | Op::LayerNorm { .. } => {
            let last = g.shape(node.inputs[0]).len().saturating_sub(1);
            let others_replicated = fs[1..].iter().all(|f| matches!(f, Replicated));
            match fs[0] {
                Partial { ranks } => partial_no_reduce(out, node, ranks),
                Sharded { dim, ranks, dist: true, .. } if dim == last => {
                    flag(
                        out,
                        "norm_shard_axis",
                        node,
                        format!(
                            "{:?} normalizes the last dim ({last}), but the input is \
                             split into {ranks} shards along it",
                            node.op.tag()
                        ),
                    );
                    Unknown
                }
                s @ Sharded { dim, .. } if dim != last && others_replicated => anon(s),
                Replicated if others_replicated => Replicated,
                _ => Unknown,
            }
        }
        Op::Rope => match (fs[0], fs[1], fs[2]) {
            (Replicated, Replicated, Replicated) => Replicated,
            (
                Sharded { dim: d0, ranks: r0, index: i0, dist: q0, .. },
                Sharded { dim: d1, ranks: r1, index: i1, dist: q1, .. },
                Sharded { dim: d2, ranks: r2, index: i2, dist: q2, .. },
            ) if d0 == d1 && d1 == d2 && r0 == r1 && r1 == r2 && i0 == i1 && i1 == i2 => {
                Sharded { dim: d0, ranks: r0, index: i0, of: ShardOf::Anon, dist: q0 || q1 || q2 }
            }
            _ => Unknown,
        },
        Op::Embedding => match (fs[0], fs[1]) {
            (Replicated, Replicated) => Replicated,
            (Replicated, Sharded { dim: 0, ranks, index, dist, .. }) => {
                Sharded { dim: 0, ranks, index, of: ShardOf::Anon, dist }
            }
            _ => Unknown,
        },
        Op::MseLoss => match (fs[0], fs[1]) {
            (Partial { ranks }, _) | (_, Partial { ranks }) => {
                partial_no_reduce(out, node, ranks)
            }
            (Replicated, Replicated) => Replicated,
            _ => Unknown, // per-shard losses are legitimately averaged later
        },

        // ---- reductions across ranks ----
        Op::SumN => {
            if !fs.is_empty()
                && fs.iter().all(|f| matches!(f, Partial { ranks } if *ranks == fs.len()))
            {
                Replicated
            } else if !fs.is_empty() && fs.iter().all(|f| matches!(f, Replicated)) {
                Replicated
            } else {
                Unknown
            }
        }

        // ---- collectives ----
        Op::AllReduce { ranks } => {
            if *ranks != fs.len() {
                flag(
                    out,
                    "collective_arity",
                    node,
                    format!("AllReduce declares ranks={ranks} but has {} inputs", fs.len()),
                );
            }
            Replicated
        }
        Op::AllGather { dim, ranks } => {
            if *ranks != fs.len() {
                flag(
                    out,
                    "collective_arity",
                    node,
                    format!("AllGather declares ranks={ranks} but has {} inputs", fs.len()),
                );
            }
            check_gather(node, *dim, fs, out)
        }
        Op::Concat { dim } => check_gather(node, *dim, fs, out),
        Op::ReduceScatter { dim, ranks, index } => {
            if *ranks != fs.len() {
                flag(
                    out,
                    "collective_arity",
                    node,
                    format!("ReduceScatter declares ranks={ranks} but has {} inputs", fs.len()),
                );
            }
            if !fs.is_empty()
                && fs.iter().all(|f| matches!(f, Sharded { dist: true, .. }))
            {
                flag(
                    out,
                    "scatter_over_shards",
                    node,
                    "ReduceScatter sums collective-produced shards — these are chunks \
                     of the full value, not addends; an AllGather/Concat was expected"
                        .to_string(),
                );
                return Unknown;
            }
            if !fs.is_empty()
                && fs.iter().all(|f| matches!(f, Partial { ranks: r } if *r == fs.len()))
                && *ranks == fs.len()
            {
                Sharded { dim: *dim, ranks: *ranks, index: *index, of: ShardOf::Anon, dist: true }
            } else {
                Unknown
            }
        }

        // ---- MoE routing ----
        Op::TopK { .. } => match fs[0] {
            Replicated => Replicated,
            _ => Unknown,
        },
        Op::Dispatch { capacity, .. } => {
            let rows = g.shape(node.inputs[0]).first().copied().unwrap_or(0);
            if (*capacity as i64) < rows {
                flag(
                    out,
                    "dispatch_capacity",
                    node,
                    format!(
                        "dispatch capacity {capacity} < {rows} rows: overflowing tokens \
                         are silently zeroed"
                    ),
                );
            }
            Unknown
        }
        Op::Combine { experts } => {
            check_combine(g, node, *experts, out);
            Unknown
        }

        Op::Custom { .. } => Unknown,
    }
}
