//! ShardFlow: pre-saturation static analysis of distributed graphs.
//!
//! An O(|G|) pass that runs *before* e-graph saturation and produces
//! [`LintFinding`]s — node-precise diagnostics for the distribution bugs
//! that are visible to a linear dataflow walk, without paying for
//! saturation. Two layers:
//!
//! 1. **Distribution-lattice dataflow** ([`placement`], [`transfer`]):
//!    per-tensor placement facts (`Replicated` / `Sharded` / `Partial` /
//!    `Unknown`) seeded from the iterative relation `R_i` and pushed
//!    through every op by a transfer function. Contradictions (a partial
//!    sum hitting an activation, a softmax over a collectively-split axis,
//!    shards re-gathered out of order, collective arity ≠ inputs, MoE
//!    mis-routing) become findings.
//! 2. **Channel wiring** ([`channels`]): the `Send`/`Recv` graph must be a
//!    well-formed matching (no crossed/orphaned/duplicated channels, buffer
//!    epochs contiguous per slot) and the contracted stage graph must be
//!    acyclic (a cycle is a communication deadlock under any schedule).
//!
//! ## Soundness contract
//!
//! The lint **never changes a verdict**: every `Verifier` run attaches
//! findings to its report, but Verified/Refuted/Inconclusive comes from the
//! e-graph oracle alone, and the canonical report (the `--canonical`
//! byte-determinism surface) excludes findings entirely. Dually, the
//! analysis must be **false-alarm-free**: a clean (G_s, G_d, R_i) triple
//! yields zero findings — every transfer rule goes to `Unknown` silently
//! unless the contradiction is definite. The fuzz oracle enforces both
//! directions with triage counters (`lint_flagged` / `lint_silent_refuted`
//! / `lint_false_alarms`; a false alarm on a clean pair fails `sound()`).
//!
//! ## Finding codes
//!
//! | code | meaning |
//! |---|---|
//! | `partial_no_reduce` | unreduced partial sum consumed by a nonlinear op |
//! | `softmax_shard_axis` | softmax along a collectively-split dim |
//! | `norm_shard_axis` | RmsNorm/LayerNorm over a split last dim |
//! | `elementwise_shard_mismatch` | elementwise op on misaligned shards |
//! | `gather_order` | shards gathered duplicated / out of rank order |
//! | `gather_mixed_source` | gather mixes shards of different tensors |
//! | `gather_dim_mismatch` | gather dim ≠ the dim the shards split |
//! | `scatter_over_shards` | ReduceScatter sums chunks instead of addends |
//! | `collective_arity` | collective `ranks` attr ≠ number of inputs |
//! | `dispatch_capacity` | MoE dispatch capacity < token rows |
//! | `combine_expert_mismatch` | combine slot fed by the wrong dispatch |
//! | `combine_gate_unnormalized` | gate weights not per-row normalized |
//! | `send_orphan` | send whose value no recv consumes |
//! | `recv_unmatched` | recv not wired to any send output |
//! | `chan_crossed` | recv wired to a send on a different channel |
//! | `chan_duplicate` | one channel id used by two sends / two recvs |
//! | `buffer_epoch_gap` | non-contiguous buffer-slot epoch sequence |
//! | `stage_cycle` | stage-graph cycle (communication deadlock) |
//!
//! The patch impact analysis ([`impact`]) reports on the same surface with
//! `IMPACT_*` codes (`IMPACT_RETAG`, `IMPACT_QUARANTINE_CROSS`,
//! `IMPACT_RELATION_LEAF`, `IMPACT_CONE_SHIFT`) — diagnostics about what a
//! [`crate::ir::GraphPatch`] does to verification semantics, not about the
//! graph itself.

pub mod channels;
pub mod impact;
pub mod placement;
pub mod report;
pub mod transfer;

pub use impact::{analyze_patch, remap_relation, ImpactReport, RegionClass, RegionImpact};
pub use placement::{Fact, ShardOf};
pub use report::{LintFinding, LintReport};

use crate::ir::Graph;
use crate::relation::Relation;

/// Run the full static analysis on a distributed graph.
///
/// `ri` (when available) seeds input placement facts from the relation; a
/// `None` relation runs the channel lints and whatever dataflow can be done
/// from an all-`Unknown` seeding (still enough for wiring and structural
/// MoE checks).
pub fn analyze(gd: &Graph, ri: Option<&Relation>) -> LintReport {
    let mut findings = Vec::new();
    let seeds = match ri {
        Some(r) => placement::seed_facts(gd, r),
        None => Default::default(),
    };
    transfer::propagate(gd, &seeds, &mut findings);
    channels::check(gd, &mut findings);
    let mut report = LintReport { findings };
    report.normalize();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    fn lint(g: &Graph) -> LintReport {
        analyze(g, None)
    }

    fn codes(r: &LintReport) -> Vec<&'static str> {
        r.findings.iter().map(|f| f.code).collect()
    }

    #[test]
    fn clean_boundary_is_silent() {
        let mut g = Graph::new("clean_pp");
        let x = g.input("x", vec![4, 4]);
        let t = g.op("stage0", Op::Identity, vec![x]);
        let s = g.op("b0_send", Op::Send { chan: 0 }, vec![t]);
        let r = g.op("b0_recv", Op::Recv { chan: 0 }, vec![s]);
        let y = g.op("stage1", Op::Identity, vec![r]);
        g.mark_output(y);
        assert!(lint(&g).is_clean());
    }

    #[test]
    fn crossed_and_orphaned_wiring_flagged() {
        let mut g = Graph::new("crossed");
        let x = g.input("x", vec![4, 4]);
        let s0 = g.op("s0", Op::Send { chan: 0 }, vec![x]);
        let s1 = g.op("s1", Op::Send { chan: 1 }, vec![x]);
        // r0 reads s1's value: crossed; s0's value is never received: orphan
        let r0 = g.op("r0", Op::Recv { chan: 0 }, vec![s1]);
        let _ = s0;
        g.mark_output(r0);
        let rep = lint(&g);
        assert!(codes(&rep).contains(&"chan_crossed"), "{rep:?}");
        assert!(codes(&rep).contains(&"send_orphan"), "{rep:?}");
    }

    #[test]
    fn recv_of_graph_input_is_unmatched() {
        let mut g = Graph::new("dropped");
        let x = g.input("x", vec![4, 4]);
        let r = g.op("r0", Op::Recv { chan: 0 }, vec![x]);
        g.mark_output(r);
        assert_eq!(codes(&lint(&g)), vec!["recv_unmatched"]);
    }

    #[test]
    fn duplicate_channel_flagged() {
        let mut g = Graph::new("dup");
        let x = g.input("x", vec![4, 4]);
        let s0 = g.op("s0", Op::Send { chan: 7 }, vec![x]);
        let s1 = g.op("s1", Op::Send { chan: 7 }, vec![x]);
        let r0 = g.op("r0", Op::Recv { chan: 7 }, vec![s0]);
        let r1 = g.op("r1", Op::Recv { chan: 7 }, vec![s1]);
        let y = g.op("y", Op::Add, vec![r0, r1]);
        g.mark_output(y);
        let rep = lint(&g);
        assert!(codes(&rep).contains(&"chan_duplicate"), "{rep:?}");
    }

    #[test]
    fn buffer_epoch_gap_flagged() {
        use crate::schedule::buffer_tag;
        let mut g = Graph::new("epochs");
        let x = g.input("x", vec![4, 4]);
        // slot 0 at boundary 0 written in epochs {0, 2}: epoch 1 missing
        let s0 = g.op("s0", Op::Send { chan: buffer_tag(0, 0, 0) }, vec![x]);
        let s1 = g.op("s1", Op::Send { chan: buffer_tag(0, 0, 2) }, vec![x]);
        let r0 = g.op("r0", Op::Recv { chan: buffer_tag(0, 0, 0) }, vec![s0]);
        let r1 = g.op("r1", Op::Recv { chan: buffer_tag(0, 0, 2) }, vec![s1]);
        let y = g.op("y", Op::Add, vec![r0, r1]);
        g.mark_output(y);
        let rep = lint(&g);
        assert!(codes(&rep).contains(&"buffer_epoch_gap"), "{rep:?}");
    }

    #[test]
    fn stage_cycle_detected() {
        // Stage A = {t, u, r1} (t feeds u, r1 feeds u), stage B = {r0, s1}:
        // A sends to B (s0→r0) and B sends back to A (s1→r1) — deadlock.
        let mut g = Graph::new("cycle");
        let x = g.input("x", vec![4, 4]);
        let t = g.op("t", Op::Identity, vec![x]);
        let s0 = g.op("s0", Op::Send { chan: 0 }, vec![t]);
        let r0 = g.op("r0", Op::Recv { chan: 0 }, vec![s0]);
        let s1 = g.op("s1", Op::Send { chan: 1 }, vec![r0]);
        let r1 = g.op("r1", Op::Recv { chan: 1 }, vec![s1]);
        let u = g.op("u", Op::Add, vec![t, r1]);
        g.mark_output(u);
        let rep = lint(&g);
        assert!(codes(&rep).contains(&"stage_cycle"), "{rep:?}");
    }

    #[test]
    fn acyclic_two_stage_chain_has_no_cycle() {
        let mut g = Graph::new("chain");
        let x = g.input("x", vec![4, 4]);
        let t = g.op("t", Op::Identity, vec![x]);
        let s0 = g.op("s0", Op::Send { chan: 0 }, vec![t]);
        let r0 = g.op("r0", Op::Recv { chan: 0 }, vec![s0]);
        let u = g.op("u", Op::Identity, vec![r0]);
        let s1 = g.op("s1", Op::Send { chan: 1 }, vec![u]);
        let r1 = g.op("r1", Op::Recv { chan: 1 }, vec![s1]);
        let v = g.op("v", Op::Identity, vec![r1]);
        g.mark_output(v);
        assert!(lint(&g).is_clean());
    }

    #[test]
    fn dispatch_capacity_flagged() {
        let mut g = Graph::new("cap");
        let x = g.input("x", vec![4, 4]);
        let router = g.input("router", vec![4, 2]);
        let d = g.op("disp", Op::Dispatch { expert: 0, capacity: 1 }, vec![x, router]);
        g.mark_output(d);
        let rep = lint(&g);
        assert_eq!(codes(&rep), vec!["dispatch_capacity"]);
    }
}
