//! Patch impact analysis — static dirty-cone diffing for incremental
//! re-verification.
//!
//! Given the old implementation graph, the patched one, and the initial
//! relation `R_i`, this pass runs **before any e-graph work** and decides,
//! per `G_s` region, whether the patch can possibly change what the
//! saturation walk sees there:
//!
//! * [`RegionClass::Clean`] — *proven* untouched: no tensor in the region's
//!   explorable `G_d` cone was edited, and the cone's structure is
//!   identical in both graphs. The region's fingerprint key
//!   ([`crate::cache::fingerprint_region`]) is therefore byte-equal to the
//!   old run's, so its cached certificate is reusable — soundly, not
//!   fingerprint-lucky (see `EXPERIMENTS.md §Incremental re-verification`
//!   for the induction).
//! * [`RegionClass::BoundaryShifted`] — the only edits reaching the region
//!   are `Send`/`Recv` channel retags with identical wiring. Shapes and
//!   dataflow are unchanged, but channel identity is part of `R_i`'s
//!   semantics, so the region must re-verify.
//! * [`RegionClass::Dirty`] — an operator, wiring, or shape edit reaches
//!   the region's cone; it must re-saturate.
//!
//! The per-region cone is the same forward closure the fingerprint
//! serializes — "add a `G_d` node once all of its inputs are related" —
//! seeded from the region's initial mappings plus (recursively) the cones
//! of its producer regions, which over-approximates every leaf the walk
//! can ever hand the region. Findings ride the [`LintFinding`] surface so
//! patches that *silently* change `R_i` semantics (channel retags,
//! quarantine crossings, edits under initial-mapping leaves) surface even
//! when every verdict stays green.

use crate::analysis::report::{LintFinding, LintReport};
use crate::egraph::CleanCand;
use crate::ir::{Graph, NodeId, Op, TensorId};
use crate::relation::Relation;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use rustc_hash::{FxHashMap, FxHashSet};
use std::fmt;

/// What the patch can do to a region's verification inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RegionClass {
    /// No edit reaches the region's cone; certificate reuse is proven sound.
    Clean,
    /// Only consistent channel retags reach the cone — structure unchanged,
    /// `R_i` channel semantics shifted; re-verify.
    BoundaryShifted,
    /// A structural/shape edit reaches the cone; re-saturate.
    Dirty,
}

impl RegionClass {
    pub fn name(self) -> &'static str {
        match self {
            RegionClass::Clean => "clean",
            RegionClass::BoundaryShifted => "boundary_shifted",
            RegionClass::Dirty => "dirty",
        }
    }
}

impl fmt::Display for RegionClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Classification of one `G_s` region (= one `G_s` operator).
#[derive(Debug, Clone)]
pub struct RegionImpact {
    pub node: NodeId,
    pub node_name: String,
    pub class: RegionClass,
}

/// The full pre-saturation impact report.
#[derive(Debug, Clone, Default)]
pub struct ImpactReport {
    /// One entry per `G_s` node, in topological (walk) order.
    pub regions: Vec<RegionImpact>,
    /// Names of directly edited `G_d` tensors (sorted).
    pub changed: Vec<String>,
    /// Forward taint cone over the *patched* graph: every `G_d` tensor a
    /// direct edit can influence (sorted ids, patched-graph numbering).
    pub tainted: Vec<TensorId>,
    /// `LintFinding`-style diagnostics (`IMPACT_*` codes), normalized.
    pub findings: Vec<LintFinding>,
}

impl ImpactReport {
    pub fn count(&self, class: RegionClass) -> usize {
        self.regions.iter().filter(|r| r.class == class).count()
    }

    pub fn clean(&self) -> usize {
        self.count(RegionClass::Clean)
    }

    /// Regions that must re-verify (`Dirty` + `BoundaryShifted`).
    pub fn dirty_cone(&self) -> usize {
        self.regions.len() - self.clean()
    }

    pub fn class_of(&self, node: NodeId) -> Option<RegionClass> {
        self.regions.iter().find(|r| r.node == node).map(|r| r.class)
    }

    pub fn is_tainted(&self, t: TensorId) -> bool {
        self.tainted.binary_search(&t).is_ok()
    }

    /// Deterministic JSON (sorted regions/findings, no timings).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("regions", Json::num(self.regions.len() as f64)),
            ("clean", Json::num(self.clean() as f64)),
            ("dirty", Json::num(self.count(RegionClass::Dirty) as f64)),
            (
                "boundary_shifted",
                Json::num(self.count(RegionClass::BoundaryShifted) as f64),
            ),
            (
                "changed",
                Json::arr(self.changed.iter().map(|c| Json::str(c.clone())).collect()),
            ),
            (
                "classes",
                Json::arr(
                    self.regions
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("node", Json::str(r.node_name.clone())),
                                ("class", Json::str(r.class.name())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "findings",
                Json::arr(self.findings.iter().map(LintFinding::to_json).collect()),
            ),
        ])
    }

    /// One-paragraph plain-text summary (CLI stderr).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "impact: {} region(s) — {} clean, {} dirty, {} boundary-shifted; \
             {} G_d tensor(s) edited",
            self.regions.len(),
            self.clean(),
            self.count(RegionClass::Dirty),
            self.count(RegionClass::BoundaryShifted),
            self.changed.len(),
        );
        for r in self.regions.iter().filter(|r| r.class != RegionClass::Clean) {
            let _ = writeln!(out, "  {} region at '{}'", r.class, r.node_name);
        }
        for f in &self.findings {
            let _ = writeln!(out, "  [{}] at '{}': {}", f.code, f.node, f.detail);
        }
        out
    }
}

/// Taint level a direct edit (or its forward propagation) carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Taint {
    None,
    Retag,
    Hard,
}

/// Re-key a relation from the old graph's `TensorId`s onto the patched
/// graph's, matching leaves by tensor *name* (patches keep names stable;
/// splices shift ids). A leaf whose tensor the patch deleted is a hard
/// error — the caller must supply an updated `R_i` in that case.
pub fn remap_relation(ri: &Relation, old_gd: &Graph, new_gd: &Graph) -> Result<Relation> {
    use crate::expr::{Expr, Side, TensorRef};
    let mut out = Relation::new();
    for t in ri.tensors() {
        for cand in ri.get(t) {
            // `substitute` keeps unmatched leaves untouched, which would
            // silently alias an old id onto an unrelated new tensor — so
            // check every leaf resolves *before* substituting.
            for l in &cand.leaves {
                let name = &old_gd.tensor(l.id).name;
                if l.side == Side::D && new_gd.tensor_by_name(name).is_none() {
                    return Err(anyhow!(
                        "R_i mapping for G_s tensor #{t} references G_d tensor '{name}', \
                         which the patch removed or renamed — supply an updated relation",
                    ));
                }
            }
            let expr = cand.expr.substitute(&|l: TensorRef| {
                if l.side != Side::D {
                    return None;
                }
                let name = &old_gd.tensor(l.id).name;
                new_gd.tensor_by_name(name).map(|id| Expr::Leaf(TensorRef::d(id)))
            });
            let leaves = expr.leaves();
            out.insert(t, CleanCand { expr, cost: cand.cost, leaves });
        }
    }
    Ok(out)
}

/// Run the static impact analysis. `ri_old` is keyed by `old_gd` ids,
/// `ri_new` by `new_gd` ids (see [`remap_relation`]); `quarantined` is the
/// channel quarantine set the verifier will run with.
pub fn analyze_patch(
    gs: &Graph,
    old_gd: &Graph,
    new_gd: &Graph,
    ri_old: &Relation,
    ri_new: &Relation,
    quarantined: &[usize],
) -> ImpactReport {
    let q: FxHashSet<usize> = quarantined.iter().copied().collect();
    let mut findings: Vec<LintFinding> = Vec::new();

    // ---- direct edits: name-aligned old/new tensor diff ----
    let mut direct: Vec<Taint> = vec![Taint::None; new_gd.num_tensors()];
    let mut changed: Vec<String> = Vec::new();
    for tid in 0..new_gd.num_tensors() as TensorId {
        let t = new_gd.tensor(tid);
        let taint = match old_gd.tensor_by_name(&t.name) {
            None => Taint::Hard, // spliced-in tensor
            Some(old_id) => {
                let ot = old_gd.tensor(old_id);
                if ot.shape != t.shape || ot.dtype != t.dtype {
                    Taint::Hard
                } else {
                    diff_producer(old_gd, old_id, new_gd, tid, &q, &mut findings)
                }
            }
        };
        if taint != Taint::None {
            changed.push(t.name.clone());
        }
        direct[tid as usize] = taint;
    }
    changed.sort_unstable();

    // ---- forward taint closure over the patched graph ----
    // Node outputs inherit the strongest taint among their inputs; a single
    // topological pass is the fixpoint.
    let mut taint = direct;
    for nid in new_gd.topo_order() {
        let node = new_gd.node(nid);
        let flow = node
            .inputs
            .iter()
            .map(|&t| taint[t as usize])
            .max()
            .unwrap_or(Taint::None);
        let slot = &mut taint[node.output as usize];
        *slot = (*slot).max(flow);
    }
    let tainted: Vec<TensorId> = (0..new_gd.num_tensors() as TensorId)
        .filter(|&t| taint[t as usize] != Taint::None)
        .collect();

    // ---- R_i semantics: edits directly under initial-mapping leaves ----
    for t in ri_new.tensors() {
        for cand in ri_new.get(t) {
            for l in &cand.leaves {
                if taint[l.id as usize] != Taint::None {
                    findings.push(LintFinding::new(
                        "IMPACT_RELATION_LEAF",
                        new_gd.tensor(l.id).name.clone(),
                        format!(
                            "initial mapping for G_s tensor '{}' rests on an edited \
                             G_d tensor — R_i semantics changed by the patch",
                            gs.tensor(t).name
                        ),
                    ));
                }
            }
        }
    }

    // ---- per-region cones and classification ----
    let mut cones_new: Vec<FxHashSet<TensorId>> = Vec::with_capacity(gs.num_nodes());
    let mut cones_old: Vec<FxHashSet<TensorId>> = Vec::with_capacity(gs.num_nodes());
    let mut regions: Vec<RegionImpact> = Vec::with_capacity(gs.num_nodes());
    for nid in gs.topo_order() {
        let node = gs.node(nid);
        let seed = |ri: &Relation, cones: &[FxHashSet<TensorId>]| -> FxHashSet<TensorId> {
            let mut related: FxHashSet<TensorId> = FxHashSet::default();
            for &t in &node.inputs {
                for cand in ri.get(t) {
                    related.extend(cand.leaves.iter().map(|l| l.id));
                }
                if let Some(p) = gs.tensor(t).producer {
                    related.extend(cones[p as usize].iter().copied());
                }
            }
            related
        };
        let mut cone_new = seed(ri_new, &cones_new);
        close_forward(new_gd, &mut cone_new);
        let mut cone_old = seed(ri_old, &cones_old);
        close_forward(old_gd, &mut cone_old);

        let hit = cone_new.iter().map(|&t| taint[t as usize]).max().unwrap_or(Taint::None);
        let class = match hit {
            Taint::Hard => RegionClass::Dirty,
            Taint::Retag => RegionClass::BoundaryShifted,
            Taint::None => {
                // No edited tensor is reachable — but a *removed* node can
                // still change what the old cone serialized. Prove key
                // equality by comparing the cones' structure.
                if cone_signature(new_gd, &cone_new) == cone_signature(old_gd, &cone_old) {
                    RegionClass::Clean
                } else {
                    findings.push(LintFinding::new(
                        "IMPACT_CONE_SHIFT",
                        gs.tensor(node.output).name.clone(),
                        "region touches no edited tensor, but its explorable G_d cone \
                         changed structure (node removed/reordered) — re-verifying"
                            .to_string(),
                    ));
                    RegionClass::Dirty
                }
            }
        };
        regions.push(RegionImpact {
            node: nid,
            node_name: gs.tensor(node.output).name.clone(),
            class,
        });
        cones_new.push(cone_new);
        cones_old.push(cone_old);
    }

    let mut lr = LintReport { findings };
    lr.normalize();
    ImpactReport { regions, changed, tainted, findings: lr.findings }
}

/// Classify one name-aligned produced tensor: does its producer differ, and
/// if so, is the difference a pure channel retag?
fn diff_producer(
    old_gd: &Graph,
    old_id: TensorId,
    new_gd: &Graph,
    new_id: TensorId,
    quarantined: &FxHashSet<usize>,
    findings: &mut Vec<LintFinding>,
) -> Taint {
    let (old_p, new_p) = (old_gd.producer(old_id), new_gd.producer(new_id));
    let (old_node, new_node) = match (old_p, new_p) {
        (None, None) => return Taint::None, // both graph inputs
        (Some(o), Some(n)) => (o, n),
        _ => return Taint::Hard, // input became produced or vice versa
    };
    let same_wiring = old_node.inputs.len() == new_node.inputs.len()
        && old_node.inputs.iter().zip(&new_node.inputs).all(|(&o, &n)| {
            old_gd.tensor(o).name == new_gd.tensor(n).name
        });
    if same_wiring && old_node.op == new_node.op {
        return Taint::None;
    }
    if same_wiring {
        if let Some((oc, nc)) = retag_pair(&old_node.op, &new_node.op) {
            let name = &new_gd.tensor(new_id).name;
            findings.push(LintFinding::new(
                "IMPACT_RETAG",
                name.clone(),
                format!(
                    "Send/Recv channel retagged {oc} -> {nc} with unchanged wiring — \
                     R_i channel semantics silently shifted"
                ),
            ));
            if quarantined.contains(&oc) != quarantined.contains(&nc) {
                findings.push(LintFinding::new(
                    "IMPACT_QUARANTINE_CROSS",
                    name.clone(),
                    format!(
                        "retag {oc} -> {nc} crosses the quarantined-channel set — \
                         the region's verification semantics change, not just its tag"
                    ),
                ));
                return Taint::Hard;
            }
            return Taint::Retag;
        }
    }
    Taint::Hard
}

/// `Some((old_chan, new_chan))` when the two ops differ only by channel.
fn retag_pair(old: &Op, new: &Op) -> Option<(usize, usize)> {
    match (old, new) {
        (Op::Send { chan: oc }, Op::Send { chan: nc })
        | (Op::Recv { chan: oc }, Op::Recv { chan: nc })
            if oc != nc =>
        {
            Some((*oc, *nc))
        }
        _ => None,
    }
}

/// Forward closure, identical to the fingerprint's: add a node's output
/// once all of its inputs are in the set (single topological pass).
fn close_forward(gd: &Graph, related: &mut FxHashSet<TensorId>) {
    for nid in gd.topo_order() {
        let node = gd.node(nid);
        if node.inputs.iter().all(|t| related.contains(t)) {
            related.insert(node.output);
        }
    }
}

/// Structural signature of a cone, in the graph's topological order —
/// exactly the facts `fingerprint_region` serializes for the `gd[…]`
/// section (ops, wiring, shapes), keyed by stable names instead of ids.
fn cone_signature(gd: &Graph, cone: &FxHashSet<TensorId>) -> Vec<String> {
    let mut sig: Vec<String> = cone
        .iter()
        .filter(|t| gd.tensor(**t).producer.is_none())
        .map(|&t| {
            let ten = gd.tensor(t);
            format!("leaf {}:{:?}", ten.name, ten.shape)
        })
        .collect();
    sig.sort_unstable();
    for nid in gd.topo_order() {
        let node = gd.node(nid);
        if !cone.contains(&node.output) || gd.tensor(node.output).producer.is_none() {
            continue;
        }
        if !node.inputs.iter().all(|t| cone.contains(t)) {
            continue;
        }
        let ins: Vec<&str> =
            node.inputs.iter().map(|&t| gd.tensor(t).name.as_str()).collect();
        sig.push(format!(
            "{:?}|{}>{}:{:?}",
            node.op,
            ins.join(","),
            gd.tensor(node.output).name,
            gd.shape(node.output)
        ));
    }
    sig
}

/// ShardFlow over the dirty cone only: merge the old report's findings for
/// nodes outside the taint cone (provably unchanged) with the fresh
/// findings inside it, and *assert* the two agree outside the cone. A
/// mismatch means the impact analysis under-approximated — surfaced as an
/// error, never silently absorbed (the fuzz triage gate keeps
/// `lint_false_alarms == 0` on clean patched pairs).
pub fn relint(
    old_full: &LintReport,
    new_full: &LintReport,
    old_gd: &Graph,
    new_gd: &Graph,
    report: &ImpactReport,
) -> Result<LintReport> {
    // A finding is "inside the cone" if its anchor node resolves to a
    // tainted tensor; unresolvable anchors are conservatively inside.
    let outside = |gd: &Graph, f: &LintFinding| -> bool {
        match gd.tensor_by_name(&f.node) {
            Some(t) if gd.tensor(t).producer.is_some() => {
                // compare via the patched graph's taint cone, matching by name
                match new_gd.tensor_by_name(&f.node) {
                    Some(nt) => !report.is_tainted(nt),
                    None => false,
                }
            }
            _ => false,
        }
    };
    let old_outside: Vec<&LintFinding> =
        old_full.findings.iter().filter(|f| outside(old_gd, f)).collect();
    let new_outside: Vec<&LintFinding> =
        new_full.findings.iter().filter(|f| outside(new_gd, f)).collect();
    if old_outside != new_outside {
        return Err(anyhow!(
            "impact invariant violated: lint findings outside the dirty cone \
             changed ({} old vs {} new) — the static cone under-approximated",
            old_outside.len(),
            new_outside.len()
        ));
    }
    let mut merged = LintReport {
        findings: old_outside
            .into_iter()
            .cloned()
            .chain(new_full.findings.iter().filter(|f| !outside(new_gd, f)).cloned())
            .collect(),
    };
    merged.normalize();
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphPatch;
    use crate::util::json::Json;

    /// fig1 running example: C = A·B (TP over 2 ranks), F = C - E.
    fn fig1() -> (Graph, Graph, Relation) {
        let mut gs = Graph::new("fig1_gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let e = gs.input("E", vec![4, 4]);
        let c = gs.matmul("C", a, b);
        let f = gs.sub2("F", c, e);
        gs.mark_output(f);

        let mut gd = Graph::new("fig1_gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let b2 = gd.input("B_2", vec![3, 4]);
        let e1 = gd.input("E_1", vec![2, 4]);
        let e2 = gd.input("E_2", vec![2, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        let c2 = gd.matmul("C_2", a2, b2);
        let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
        let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
        let f1 = gd.sub2("F_1", d1, e1);
        let f2 = gd.sub2("F_2", d2, e2);
        let f = gd.all_gather("F_full", vec![f1, f2], 0);
        gd.mark_output(f);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{
                "A": ["concat(A_1, A_2; dim=1)"],
                "B": ["concat(B_1, B_2; dim=0)"],
                "E": ["concat(E_1, E_2; dim=0)"]
            }"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        (gs, gd, ri)
    }

    fn classes(report: &ImpactReport) -> Vec<(String, RegionClass)> {
        report.regions.iter().map(|r| (r.node_name.clone(), r.class)).collect()
    }

    #[test]
    fn unpatched_pair_is_all_clean() {
        let (gs, gd, ri) = fig1();
        let report = analyze_patch(&gs, &gd, &gd, &ri, &ri, &[]);
        assert_eq!(report.regions.len(), gs.num_nodes());
        assert!(report.regions.iter().all(|r| r.class == RegionClass::Clean), "{report:?}");
        assert!(report.changed.is_empty());
        assert!(report.findings.is_empty());
    }

    #[test]
    fn late_edit_leaves_upstream_clean() {
        let (gs, gd, ri) = fig1();
        // edit F_1 (sub -> add): region C never reaches it, region F does
        let patched = GraphPatch::new("bug").replace("F_1", Op::Add).apply(&gd).unwrap();
        let ri_new = remap_relation(&ri, &gd, &patched).unwrap();
        let report = analyze_patch(&gs, &gd, &patched, &ri, &ri_new, &[]);
        let by_name: FxHashMap<String, RegionClass> = classes(&report).into_iter().collect();
        assert_eq!(by_name["C"], RegionClass::Clean, "{report:?}");
        assert_eq!(by_name["F"], RegionClass::Dirty, "{report:?}");
        assert_eq!(report.changed, vec!["F_1".to_string()]);
    }

    #[test]
    fn early_edit_dirties_the_forward_cone() {
        let (gs, gd, ri) = fig1();
        let patched =
            GraphPatch::new("bug").rewire("C_2", 0, "A_1").apply(&gd).unwrap();
        let ri_new = remap_relation(&ri, &gd, &patched).unwrap();
        let report = analyze_patch(&gs, &gd, &patched, &ri, &ri_new, &[]);
        // C_2 feeds both regions' cones: everything re-verifies
        assert!(report.regions.iter().all(|r| r.class == RegionClass::Dirty), "{report:?}");
    }

    #[test]
    fn consistent_retag_is_boundary_shifted() {
        let mut gs = Graph::new("gs");
        let x = gs.input("X", vec![4]);
        let y = gs.op("Y", Op::Neg, vec![x]);
        gs.mark_output(y);
        let mut gd = Graph::new("gd");
        let xd = gd.input("X_d", vec![4]);
        let s = gd.op("snd", Op::Send { chan: 1 }, vec![xd]);
        let r = gd.op("rcv", Op::Recv { chan: 1 }, vec![s]);
        let yd = gd.op("Y_d", Op::Neg, vec![r]);
        gd.mark_output(yd);
        let ri = Relation::from_json(
            &Json::parse(r#"{"X": ["X_d"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let patched =
            GraphPatch::new("retag").retag("snd", 5).retag("rcv", 5).apply(&gd).unwrap();
        let ri_new = remap_relation(&ri, &gd, &patched).unwrap();
        let report = analyze_patch(&gs, &gd, &patched, &ri, &ri_new, &[]);
        assert!(
            report.regions.iter().all(|r| r.class == RegionClass::BoundaryShifted),
            "{report:?}"
        );
        assert!(report.findings.iter().any(|f| f.code == "IMPACT_RETAG"), "{report:?}");
        // the same retag across the quarantine set escalates to Dirty
        let report_q = analyze_patch(&gs, &gd, &patched, &ri, &ri_new, &[5]);
        assert!(
            report_q.regions.iter().all(|r| r.class == RegionClass::Dirty),
            "{report_q:?}"
        );
        assert!(
            report_q.findings.iter().any(|f| f.code == "IMPACT_QUARANTINE_CROSS"),
            "{report_q:?}"
        );
    }

    #[test]
    fn dead_node_removal_is_a_cone_shift_not_a_silent_clean() {
        let mut gs = Graph::new("gs");
        let x = gs.input("X", vec![4]);
        let y = gs.op("Y", Op::Neg, vec![x]);
        gs.mark_output(y);
        let mut gd = Graph::new("gd");
        let xd = gd.input("X_d", vec![4]);
        // dead: consumes X_d but feeds nothing
        let dead = gd.op("dead", Op::Exp, vec![xd]);
        let _ = dead;
        let yd = gd.op("Y_d", Op::Neg, vec![xd]);
        gd.mark_output(yd);
        let ri = Relation::from_json(
            &Json::parse(r#"{"X": ["X_d"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let patched = GraphPatch::new("rm").remove("dead", "X_d").apply(&gd).unwrap();
        let ri_new = remap_relation(&ri, &gd, &patched).unwrap();
        let report = analyze_patch(&gs, &gd, &patched, &ri, &ri_new, &[]);
        // no reachable tensor changed, but the old cone serialized 'dead':
        // the key differs, so Clean would be a lie
        assert!(
            report.regions.iter().all(|r| r.class == RegionClass::Dirty),
            "{report:?}"
        );
        assert!(report.findings.iter().any(|f| f.code == "IMPACT_CONE_SHIFT"), "{report:?}");
    }

    #[test]
    fn remap_relation_rejects_deleted_leaves() {
        let (gs, gd, ri) = fig1();
        let _ = gs;
        // build a gd' that renames E_1 away
        let mut gd2 = Graph::new("fig1_gd");
        for &i in &gd.inputs {
            let t = gd.tensor(i);
            let name = if t.name == "E_1" { "E_1_renamed".to_string() } else { t.name.clone() };
            gd2.input_typed(&name, t.shape.clone(), t.dtype);
        }
        let e = remap_relation(&ri, &gd, &gd2).unwrap_err();
        assert!(format!("{e:#}").contains("E_1"), "{e:#}");
    }
}
