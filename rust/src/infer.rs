//! The iterative relation-inference algorithm — the paper's core
//! contribution (Listings 1–3).
//!
//! [`crate::verifier::Verifier::run`] walks `G_s` in topological order
//! (Listing 1). For each operator it builds a *fresh, small* e-graph seeded
//! with the
//! operator's expression over already-mapped inputs, saturates it against
//! the lemma library, then iteratively unions in `G_d` definitional
//! equalities restricted to the `T_rel` frontier (Listing 3) and extracts
//! clean candidate mappings for the operator's output (Listing 2). A node
//! with no clean mapping aborts with a [`RefinementError`] naming the
//! operator — the paper's bug-localization output (§6.2).

use crate::cache::{fingerprint_region, FingerprintCache, RegionEntry};
use crate::egraph::{
    extract_clean, saturate, CleanCand, EGraph, Exhaustion, Id, RewriteCtx, SatStats,
    SaturationLimits,
};
use crate::expr::{Side, TensorRef};
use crate::ir::{Graph, NodeId, TensorId};
use crate::lemmas;
use crate::relation::Relation;
use anyhow::Result;
use rustc_hash::{FxHashMap, FxHashSet};
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct InferConfig {
    pub limits: SaturationLimits,
    /// Max frontier-expansion iterations per operator (Listing 3 loop).
    pub max_frontier_iters: usize,
    /// Per-region (per-operator) wall-clock budget. Each operator of the
    /// topological walk gets a fresh deadline; exceeding it yields
    /// `Verdict::Inconclusive(Timeout)`, never a refutation. `None`
    /// disables the deadline.
    pub region_deadline: Option<Duration>,
    /// Numerically re-check the final `R_o` on random inputs (soundness
    /// certificate). Costs one evaluation of both graphs.
    pub check_numeric: bool,
    /// Pipeline channels whose buffer slot failed the schedule's liveness
    /// audit (`schedule::quarantined_channels`): `recv_of_send_identity`
    /// refuses to collapse them even when the tags match. Empty by default.
    pub quarantined_channels: Vec<usize>,
    /// Worker threads for the region walk. `1` (the default) is the exact
    /// sequential walk; `N > 1` checks independent regions of each
    /// dependency level concurrently on a scoped worker pool with
    /// per-worker reusable e-graph arenas. Verdicts, relations, stats, and
    /// failure loci are identical for every `jobs` value — see the
    /// determinism contract in EXPERIMENTS.md.
    pub jobs: usize,
    /// Certificate fingerprint cache shared across regions (and, via
    /// [`crate::cache::FingerprintCache::global`], across jobs). `None`
    /// (the default) disables memoization; the CLI enables it for
    /// verify/suite runs. Never changes verdicts — only wall time.
    pub cache: Option<Arc<FingerprintCache>>,
}

impl Default for InferConfig {
    fn default() -> Self {
        InferConfig {
            limits: SaturationLimits::new(8, 60_000),
            max_frontier_iters: 12,
            region_deadline: Some(Duration::from_secs(30)),
            check_numeric: false,
            quarantined_channels: Vec::new(),
            jobs: 1,
            cache: None,
        }
    }
}

/// Refinement failure: the operator whose outputs could not be mapped,
/// plus the context a user needs to localize the bug (§6.2).
#[derive(Debug, Clone)]
pub struct RefinementError {
    pub node: NodeId,
    pub node_name: String,
    pub op: String,
    /// For each input: (tensor name, #mappings available, sample mapping).
    pub inputs: Vec<(String, usize, Option<String>)>,
    pub frontier_size: usize,
    pub explored_gd_nodes: usize,
    /// True when some saturation pass of the walk stopped on the iteration
    /// cap (or a frontier loop on its cap) before reaching fixpoint. The
    /// refutation is still the verdict the configured budget supports, but
    /// an escalation policy may retry it at a larger budget; a refutation
    /// with `unsaturated == false` is a fixpoint and cannot be improved.
    pub unsaturated: bool,
}

impl fmt::Display for RefinementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "refinement FAILED at operator '{}' ({}): no clean mapping for its output",
            self.node_name, self.op
        )?;
        writeln!(f, "  input relations at this operator:")?;
        for (name, n, sample) in &self.inputs {
            match sample {
                Some(s) => writeln!(f, "    {name}: {n} mapping(s), e.g. {s}")?,
                None => writeln!(f, "    {name}: NO mapping — trace the producing operator")?,
            }
        }
        write!(
            f,
            "  explored {} G_d operators over a frontier of {} related tensors;\n  \
             inspect this operator and the G_d subgraph that should compute it",
            self.explored_gd_nodes, self.frontier_size
        )
    }
}

impl std::error::Error for RefinementError {}

#[derive(Debug, Clone, Default)]
pub struct NodeTiming {
    pub node_name: String,
    pub micros: u64,
    pub egraph_nodes: usize,
    pub explored_gd: usize,
}

/// Successful inference output.
#[derive(Debug)]
pub struct InferOutput {
    /// Complete clean output relation `R_o` (restricted to `O(G_s)`; leaves
    /// restricted to `O(G_d)` where possible — see `relation_full`).
    pub relation: Relation,
    /// Mappings for every `G_s` tensor (debugging, bug-5-style inspection).
    pub relation_full: Relation,
    /// Aggregated lemma-application counts (Figure 7 raw data).
    pub stats: SatStats,
    pub per_node: Vec<NodeTiming>,
    /// Regions replayed from the fingerprint cache / computed fresh. Both
    /// zero when no cache was configured. Deterministic for `jobs = 1`;
    /// for `jobs > 1` identical regions racing within one dependency level
    /// may each count a miss (the results never vary, only the split).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Pre-saturation static-analysis findings on `G_d` (ShardFlow,
    /// [`crate::analysis`]). Diagnostics only: they ride along with the
    /// verdict and are excluded from the canonical report — the e-graph
    /// remains the sole verdict oracle.
    pub lint: Vec<crate::analysis::LintFinding>,
}

/// Why inference could not reach a verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InconclusiveReason {
    /// A region's wall-clock deadline passed (`InferConfig::region_deadline`).
    Timeout,
    /// The e-graph node budget (`SaturationLimits::max_nodes`) was exhausted
    /// and no clean mapping had been found by then.
    NodeBudget,
    /// Inference panicked (poisoned lemma applier, internal bug); caught by
    /// the isolation layer ([`crate::verifier::Verifier::isolated`]).
    Panic,
}

impl InconclusiveReason {
    pub fn tag(self) -> &'static str {
        match self {
            InconclusiveReason::Timeout => "timeout",
            InconclusiveReason::NodeBudget => "node_budget",
            InconclusiveReason::Panic => "panic",
        }
    }
}

impl fmt::Display for InconclusiveReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.tag())
    }
}

/// A resource-exhaustion (or crash) outcome: *neither* a proof *nor* a
/// refutation. The soundness-of-reporting rule is that this must never be
/// collapsed into `Refuted` — a budget blowup is not evidence of a bug.
#[derive(Debug)]
pub struct Inconclusive {
    pub reason: InconclusiveReason,
    /// The `G_s` operator being processed when the budget ran out.
    pub region: String,
    /// The relation inferred for the prefix of the walk that did complete —
    /// useful for resuming or for narrowing a manual investigation.
    pub partial_relation: Relation,
    pub detail: String,
}

impl fmt::Display for Inconclusive {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "refinement INCONCLUSIVE ({}) in region '{}': {} \
             (raise the saturation budgets or deadline and retry; \
             this is a resource verdict, not a refutation)",
            self.reason, self.region, self.detail
        )
    }
}

/// Three-valued inference verdict.
#[derive(Debug)]
pub enum Verdict {
    /// Refinement holds; carries the inferred relation (the certificate).
    Verified(Box<InferOutput>),
    /// Refinement fails; carries the localization.
    Refuted(Box<RefinementError>),
    /// Budgets ran out or a worker crashed before a verdict was reached.
    Inconclusive(Box<Inconclusive>),
}

impl Verdict {
    /// Stable string tag used by reports, journals, and JSON artifacts.
    pub fn tag(&self) -> &'static str {
        match self {
            Verdict::Verified(_) => "verified",
            Verdict::Refuted(_) => "refuted",
            Verdict::Inconclusive(i) => match i.reason {
                InconclusiveReason::Timeout => "inconclusive_timeout",
                InconclusiveReason::NodeBudget => "inconclusive_node_budget",
                InconclusiveReason::Panic => "inconclusive_panic",
            },
        }
    }

    pub fn is_verified(&self) -> bool {
        matches!(self, Verdict::Verified(_))
    }
}

std::thread_local! {
    /// Name of the `G_s` operator currently being processed on this thread,
    /// so a caught panic can still name its region.
    static CURRENT_REGION: std::cell::RefCell<String> =
        const { std::cell::RefCell::new(String::new()) };
}

/// Listing 1 under a two-valued API, kept as a deprecated compatibility
/// wrapper for external fixtures and scripts.
///
/// Panics on `Inconclusive`: silently mapping a resource verdict onto
/// either `Ok` or `Err` would be exactly the misreporting this layer
/// exists to prevent (same contract as [`crate::verifier::Verifier::expect`]).
#[deprecated(
    since = "0.1.0",
    note = "use graphguard::verifier::Verifier::new().expect(gs, gd, ri) \
            (migration table in EXPERIMENTS.md §Serve)"
)]
pub fn check_refinement(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
) -> Result<InferOutput, RefinementError> {
    crate::verifier::Verifier::with_config(cfg.clone()).expect(gs, gd, ri)
}

/// Deprecated wrapper over [`crate::verifier::Verifier`] with
/// `isolated(true)`: a panicking lemma applier (or any internal bug)
/// becomes `Inconclusive(Panic)` with the payload preserved, instead of
/// unwinding into the caller.
#[deprecated(
    since = "0.1.0",
    note = "use graphguard::verifier::Verifier::with_config(cfg).isolated(true).run(gs, gd, ri) \
            (migration table in EXPERIMENTS.md §Serve)"
)]
pub fn check_refinement_isolated(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
) -> Verdict {
    crate::verifier::Verifier::with_config(cfg.clone()).isolated(true).run(gs, gd, ri)
}

/// [`verdict_core`] wrapped in `catch_unwind`: a panicking lemma applier
/// becomes `Inconclusive(Panic)` with the payload preserved. The e-graph
/// arena and rewrite context are local to the call, so the poisoned state
/// is dropped, not reused. This is the isolation layer behind
/// [`crate::verifier::Verifier::isolated`].
pub(crate) fn isolated_core(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
) -> Verdict {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        verdict_core(gs, gd, ri, cfg)
    }));
    match result {
        Ok(v) => v,
        Err(payload) => {
            let detail = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic with non-string payload".to_string());
            let region = CURRENT_REGION.with(|r| std::mem::take(&mut *r.borrow_mut()));
            Verdict::Inconclusive(Box::new(Inconclusive {
                reason: InconclusiveReason::Panic,
                region: if region.is_empty() { "<unknown>".to_string() } else { region },
                partial_relation: Relation::default(),
                detail,
            }))
        }
    }
}

/// Deprecated wrapper over [`crate::verifier::Verifier::run`] (no
/// isolation, no escalation): Listing 1, three-valued.
#[deprecated(
    since = "0.1.0",
    note = "use graphguard::verifier::Verifier::with_config(cfg).run(gs, gd, ri) \
            (migration table in EXPERIMENTS.md §Serve)"
)]
pub fn check_refinement_verdict(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
) -> Verdict {
    crate::verifier::Verifier::with_config(cfg.clone()).run(gs, gd, ri)
}

/// Listing 1: compute the output relation, iterating operators of `G_s`.
/// Three-valued: resource exhaustion yields `Inconclusive`, never `Refuted`.
/// The single saturation entry point every [`crate::verifier::Verifier`]
/// mode bottoms out in.
pub(crate) fn verdict_core(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
) -> Verdict {
    // ShardFlow pre-pass: O(|G_d|) static diagnostics, attached to a
    // Verified output below. Never consulted for the verdict itself.
    let lint = crate::analysis::analyze(gd, Some(ri)).findings;
    let rules = lemmas::standard_rewrites();
    let quarantined: FxHashSet<usize> = cfg.quarantined_channels.iter().copied().collect();
    // While any chaos fault is armed, bypass the cache entirely: a replayed
    // region skips its lemma applications (shifting which application is
    // the fault's "Nth"), and nothing computed mid-fault may be stored.
    let cache =
        if crate::chaos::any_armed() { None } else { cfg.cache.as_deref() };
    let walk = if cfg.jobs > 1 && gs.num_nodes() > 1 {
        walk_parallel(gs, gd, ri, cfg, &rules, cache, &quarantined)
    } else {
        walk_sequential(gs, gd, ri, cfg, &rules, cache, &quarantined)
    };
    let WalkOk { r, stats, per_node, cache_hits, cache_misses } = match walk {
        Ok(w) => w,
        Err(v) => return v,
    };

    // Listing 1 line 9: restrict to O(G_s) with leaves in O(G_d). An output
    // with no such expression means G_d's outputs cannot reconstruct it —
    // an incomplete R_o, i.e. a bug (§3.1), reported against the producing
    // operator.
    let out_ok = |t: TensorRef| t.side == Side::D && gd.is_output(t.id);
    let ro = r.restrict(&gs.outputs, out_ok);
    for &o in &gs.outputs {
        if !ro.contains(o) {
            let node = gs
                .producer(o)
                .map(|n| n.name.clone())
                .unwrap_or_else(|| gs.tensor(o).name.clone());
            let nid = gs
                .topo_order()
                .find(|&n| gs.node(n).output == o)
                .unwrap_or(0);
            let e = RefinementError {
                node: nid,
                node_name: node,
                op: "output filter".into(),
                inputs: vec![(
                    gs.tensor(o).name.clone(),
                    r.get(o).len(),
                    r.get(o).first().map(|c| {
                        crate::expr::print::render(
                            &c.expr,
                            &crate::expr::print::Namer { gs, gd },
                        )
                    }),
                )],
                frontier_size: 0,
                explored_gd_nodes: 0,
                unsaturated: false,
            };
            return fail_verdict(e, &stats, r);
        }
    }
    Verdict::Verified(Box::new(InferOutput {
        relation: ro,
        relation_full: r,
        stats,
        per_node,
        cache_hits,
        cache_misses,
        lint,
    }))
}

/// A completed topological walk (the happy path of Listing 1, before the
/// output filter).
struct WalkOk {
    r: Relation,
    stats: SatStats,
    per_node: Vec<NodeTiming>,
    cache_hits: u64,
    cache_misses: u64,
}

/// Outcome of one region (one `G_s` operator) of the walk.
enum NodeOutcome {
    Done {
        cands: Vec<CleanCand>,
        timing: NodeTiming,
        /// This region's saturation-stats delta. Merging the deltas of all
        /// regions in ascending-nid order reproduces the cumulative stats
        /// of the sequential walk exactly (`SatStats::merge` is associative
        /// with `{saturated: true, ..Default}` as identity).
        delta: SatStats,
        from_cache: bool,
    },
    Fail {
        err: RefinementError,
        delta: SatStats,
    },
}

/// Check one region: fingerprint-cache replay when possible, otherwise
/// compute via [`compute_node_out_rel`] and memoize the result.
///
/// Cache-soundness invariants enforced here:
/// - only `Ok` results whose own delta hit **no** hard budget are stored
///   (`Inconclusive` precursors and refutations are never cached);
/// - the per-region wall-clock deadline is started fresh per region and is
///   *not* part of the key — sound, because only deadline-untouched results
///   are ever stored and replaying one consumes no budget;
/// - replay merges the stored stats delta, so cold and warm walks report
///   byte-identical cumulative stats.
#[allow(clippy::too_many_arguments)]
fn process_node(
    nid: NodeId,
    gs: &Graph,
    gd: &Graph,
    r: &Relation,
    rules: &[crate::egraph::Rewrite],
    ctx: &RewriteCtx,
    cfg: &InferConfig,
    cache: Option<&FingerprintCache>,
    quarantined: &FxHashSet<usize>,
    eg: &mut EGraph,
) -> NodeOutcome {
    let fp = cache.map(|_| {
        fingerprint_region(nid, gs, gd, r, cfg.limits, cfg.max_frontier_iters, quarantined)
    });
    if let (Some(c), Some(fp)) = (cache, fp.as_ref()) {
        if let Some(entry) = c.lookup(&fp.key) {
            return NodeOutcome::Done {
                cands: fp.instantiate(&entry.cands),
                timing: NodeTiming {
                    node_name: String::new(),
                    micros: 0,
                    egraph_nodes: entry.egraph_nodes,
                    explored_gd: entry.explored_gd,
                },
                delta: entry.stats.clone(),
                from_cache: true,
            };
        }
    }
    // Fresh wall-clock budget per region: one pathological operator cannot
    // starve the rest of the walk's allowance.
    let limits = cfg
        .limits
        .with_deadline(cfg.region_deadline.map(|d| Instant::now() + d).or(cfg.limits.deadline));
    let mut delta = SatStats { saturated: true, ..Default::default() };
    match compute_node_out_rel(nid, gs, gd, r, rules, ctx, cfg, limits, eg, &mut delta) {
        Ok((cands, timing)) => {
            if let (Some(c), Some(fp)) = (cache, fp.as_ref()) {
                if delta.exhausted.is_none() {
                    if let Some(canonical) = fp.canonicalize(&cands) {
                        c.insert(
                            fp.key.clone(),
                            RegionEntry {
                                cands: canonical,
                                stats: delta.clone(),
                                egraph_nodes: timing.egraph_nodes,
                                explored_gd: timing.explored_gd,
                            },
                        );
                    }
                }
            }
            NodeOutcome::Done { cands, timing, delta, from_cache: false }
        }
        Err(err) => NodeOutcome::Fail { err, delta },
    }
}

/// The exact sequential walk of Listing 1 (`jobs = 1`), with one reused
/// e-graph arena: per-operator e-graphs are small but numerous, so keeping
/// the memo-table / class-map / union-find allocations warm is a measurable
/// win on many-operator models (see EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
fn walk_sequential(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
    rules: &[crate::egraph::Rewrite],
    cache: Option<&FingerprintCache>,
    quarantined: &FxHashSet<usize>,
) -> Result<WalkOk, Verdict> {
    let mut ctx = RewriteCtx::default();
    ctx.quarantine_channels(cfg.quarantined_channels.iter().copied());
    let mut r = ri.clone();
    let mut stats = SatStats { saturated: true, ..Default::default() };
    let mut per_node = Vec::with_capacity(gs.num_nodes());
    let (mut cache_hits, mut cache_misses) = (0u64, 0u64);
    let mut scratch = EGraph::new();

    for nid in gs.topo_order() {
        let t0 = Instant::now();
        let node = gs.node(nid);
        CURRENT_REGION.with(|reg| node.name.clone_into(&mut reg.borrow_mut()));
        match process_node(nid, gs, gd, &r, rules, &ctx, cfg, cache, quarantined, &mut scratch) {
            NodeOutcome::Done { cands, timing, delta, from_cache } => {
                stats.merge(&delta);
                if cache.is_some() {
                    if from_cache {
                        cache_hits += 1;
                    } else {
                        cache_misses += 1;
                    }
                }
                per_node.push(NodeTiming {
                    node_name: node.name.clone(),
                    micros: t0.elapsed().as_micros() as u64,
                    ..timing
                });
                r.insert_all(node.output, cands);
            }
            NodeOutcome::Fail { err, delta } => {
                stats.merge(&delta);
                let mut e = err;
                e.node = nid;
                CURRENT_REGION.with(|reg| reg.borrow_mut().clear());
                return Err(fail_verdict(e, &stats, r));
            }
        }
    }
    CURRENT_REGION.with(|reg| reg.borrow_mut().clear());
    Ok(WalkOk { r, stats, per_node, cache_hits, cache_misses })
}

enum WorkerMsg {
    Out(NodeOutcome),
    Panicked(String, Box<dyn std::any::Any + Send + 'static>),
}

/// Wavefront-parallel walk (`jobs > 1`). Regions are grouped into
/// dependency levels (a node's level is 1 + the max level of its
/// producers); nodes within a level share no producer/consumer edge, so
/// they can be checked concurrently against the same relation snapshot.
///
/// Determinism contract (tested in `rust/tests/cache.rs`): every level runs
/// to completion — a failed region's consumers simply find no mapping for
/// that input and fail immediately, which is cheap — and the walk's verdict
/// is decided by the *smallest-nid* failed or panicked region. `G_s` node
/// ids are topologically sorted (producers precede consumers), so every
/// region below that nid completed with exactly the inputs the sequential
/// walk would have given it, and the rebuilt prefix relation, merged stats,
/// failure locus, and error text are all byte-identical to `jobs = 1`.
///
/// Panic isolation: a panicking region is caught in its worker, the worker's
/// arena and rewrite context are replaced (their state is arbitrary after an
/// unwind mid-rewrite, and a poisoned condition-cache mutex would cascade
/// panics onto innocent regions), and the payload is re-thrown on the
/// calling thread only if that region is the walk's authoritative outcome —
/// exactly reproducing the sequential unwind for [`isolated_core`] to
/// convert.
#[allow(clippy::too_many_arguments)]
fn walk_parallel(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
    rules: &[crate::egraph::Rewrite],
    cache: Option<&FingerprintCache>,
    quarantined: &FxHashSet<usize>,
) -> Result<WalkOk, Verdict> {
    let mut tlvl: FxHashMap<TensorId, usize> = FxHashMap::default();
    let mut levels: Vec<Vec<NodeId>> = Vec::new();
    for nid in gs.topo_order() {
        let node = gs.node(nid);
        let lvl = node
            .inputs
            .iter()
            .filter_map(|t| tlvl.get(t))
            .map(|&l| l + 1)
            .max()
            .unwrap_or(0);
        tlvl.insert(node.output, lvl);
        if levels.len() == lvl {
            levels.push(Vec::new());
        }
        levels[lvl].push(nid); // ascending nid within each level
    }

    let jobs = cfg.jobs.max(1);
    let mk_ctx = || {
        let mut ctx = RewriteCtx::default();
        ctx.quarantine_channels(cfg.quarantined_channels.iter().copied());
        ctx
    };
    // Per-worker reusable arenas, persistent across levels.
    let mut arenas: Vec<(EGraph, RewriteCtx)> =
        (0..jobs).map(|_| (EGraph::new(), mk_ctx())).collect();
    let n = gs.num_nodes();
    let mut outcomes: Vec<Option<NodeOutcome>> = Vec::with_capacity(n);
    outcomes.resize_with(n, || None);
    let mut micros: Vec<u64> = vec![0; n];
    let mut panics: FxHashMap<NodeId, (String, Box<dyn std::any::Any + Send>)> =
        FxHashMap::default();
    let mut r = ri.clone();

    for level in &levels {
        if level.len() == 1 {
            // Single region: run inline on the calling thread, uncaught —
            // a panic propagates exactly as in the sequential walk.
            let nid = level[0];
            let t0 = Instant::now();
            let node = gs.node(nid);
            CURRENT_REGION.with(|reg| node.name.clone_into(&mut reg.borrow_mut()));
            let (eg, ctx) = &mut arenas[0];
            let out = process_node(nid, gs, gd, &r, rules, ctx, cfg, cache, quarantined, eg);
            micros[nid as usize] = t0.elapsed().as_micros() as u64;
            if let NodeOutcome::Done { cands, .. } = &out {
                r.insert_all(node.output, cands.clone());
            }
            outcomes[nid as usize] = Some(out);
            continue;
        }
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(NodeId, u64, WorkerMsg)>();
        let workers = jobs.min(level.len());
        let r_snap = &r;
        let next_ref = &next;
        let mk_ctx_ref = &mk_ctx;
        std::thread::scope(|s| {
            for arena in arenas.iter_mut().take(workers) {
                let tx = tx.clone();
                s.spawn(move || {
                    let (eg, ctx) = arena;
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        let Some(&nid) = level.get(i) else { break };
                        let node = gs.node(nid);
                        CURRENT_REGION
                            .with(|reg| node.name.clone_into(&mut reg.borrow_mut()));
                        let t0 = Instant::now();
                        let res = catch_unwind(AssertUnwindSafe(|| {
                            process_node(
                                nid, gs, gd, r_snap, rules, ctx, cfg, cache, quarantined, eg,
                            )
                        }));
                        let us = t0.elapsed().as_micros() as u64;
                        match res {
                            Ok(out) => {
                                let _ = tx.send((nid, us, WorkerMsg::Out(out)));
                            }
                            Err(payload) => {
                                let region = CURRENT_REGION
                                    .with(|reg| std::mem::take(&mut *reg.borrow_mut()));
                                let _ =
                                    tx.send((nid, us, WorkerMsg::Panicked(region, payload)));
                                // The arena and the ctx's condition cache
                                // hold arbitrary state from the unwound
                                // region; replace both so later regions on
                                // this worker cannot cascade-fail and get
                                // misblamed.
                                *eg = EGraph::new();
                                *ctx = mk_ctx_ref();
                            }
                        }
                    }
                });
            }
            drop(tx);
            for (nid, us, msg) in rx {
                micros[nid as usize] = us;
                match msg {
                    WorkerMsg::Out(out) => outcomes[nid as usize] = Some(out),
                    WorkerMsg::Panicked(region, payload) => {
                        panics.insert(nid, (region, payload));
                    }
                }
            }
        });
        // Publish this level's successes in ascending-nid order before the
        // next level reads the relation.
        for &nid in level {
            if let Some(NodeOutcome::Done { cands, .. }) = &outcomes[nid as usize] {
                r.insert_all(gs.node(nid).output, cands.clone());
            }
        }
    }

    // The walk's authoritative outcome is the smallest-nid region that
    // failed or panicked — exactly where the sequential walk would stop.
    let problem = gs.topo_order().find(|&nid| {
        panics.contains_key(&nid)
            || matches!(outcomes[nid as usize], Some(NodeOutcome::Fail { .. }))
    });
    if let Some(k) = problem {
        // Rebuild the sequential prefix: every region below k completed
        // (its producers are below k too), so merging their deltas and
        // outputs in ascending order reproduces the sequential walk state.
        let mut stats = SatStats { saturated: true, ..Default::default() };
        let mut prefix = ri.clone();
        for nid in gs.topo_order().take_while(|&nid| nid < k) {
            if let Some(NodeOutcome::Done { cands, delta, .. }) = &outcomes[nid as usize] {
                stats.merge(delta);
                prefix.insert_all(gs.node(nid).output, cands.clone());
            }
        }
        if let Some((region, payload)) = panics.remove(&k) {
            // Re-throw on the calling thread with the worker's region name,
            // for isolated_core to convert to Inconclusive(Panic) exactly
            // as in sequential mode.
            CURRENT_REGION.with(|reg| *reg.borrow_mut() = region);
            resume_unwind(payload);
        }
        let Some(NodeOutcome::Fail { err, delta }) = outcomes[k as usize].take() else {
            unreachable!("problem nid must hold a Fail outcome");
        };
        stats.merge(&delta);
        let mut e = err;
        e.node = k;
        CURRENT_REGION.with(|reg| reg.borrow_mut().clear());
        return Err(fail_verdict(e, &stats, prefix));
    }

    let mut stats = SatStats { saturated: true, ..Default::default() };
    let mut per_node = Vec::with_capacity(n);
    let (mut cache_hits, mut cache_misses) = (0u64, 0u64);
    for nid in gs.topo_order() {
        let Some(NodeOutcome::Done { timing, delta, from_cache, .. }) =
            &outcomes[nid as usize]
        else {
            unreachable!("no problem nid, so every region completed");
        };
        stats.merge(delta);
        if cache.is_some() {
            if *from_cache {
                cache_hits += 1;
            } else {
                cache_misses += 1;
            }
        }
        per_node.push(NodeTiming {
            node_name: gs.node(nid).name.clone(),
            micros: micros[nid as usize],
            egraph_nodes: timing.egraph_nodes,
            explored_gd: timing.explored_gd,
        });
    }
    CURRENT_REGION.with(|reg| reg.borrow_mut().clear());
    Ok(WalkOk { r, stats, per_node, cache_hits, cache_misses })
}

/// Classify a walk failure: if any saturation pass of the walk was cut by a
/// *hard* budget (node cap / deadline), the missing mapping may exist beyond
/// the budget — report `Inconclusive`, never `Refuted`. A merely
/// iteration-capped walk keeps the refutation but marks it `unsaturated` so
/// escalation can retry it at a larger budget.
fn fail_verdict(mut e: RefinementError, stats: &SatStats, partial: Relation) -> Verdict {
    if let Some(x) = stats.exhausted {
        let reason = match x {
            Exhaustion::Deadline => InconclusiveReason::Timeout,
            Exhaustion::NodeBudget => InconclusiveReason::NodeBudget,
        };
        let detail = format!(
            "no clean mapping for '{}' ({}) before the {} budget ran out",
            e.node_name,
            e.op,
            match x {
                Exhaustion::Deadline => "wall-clock",
                Exhaustion::NodeBudget => "e-graph node",
            }
        );
        return Verdict::Inconclusive(Box::new(Inconclusive {
            reason,
            region: e.node_name,
            partial_relation: partial,
            detail,
        }));
    }
    e.unsaturated = !stats.saturated;
    Verdict::Refuted(Box::new(e))
}

/// Iterative-deepening schedule for saturation budgets.
///
/// Jobs start at a small budget (most regions verify in a few iterations
/// over a few thousand nodes — the cheap first attempt makes the common
/// case faster) and, on `Inconclusive(NodeBudget)` or an unsaturated
/// refutation, retry with geometrically raised `max_iters`/`max_nodes`.
/// The **final** attempt never runs below the caller's base limits, so the
/// escalated verdict is at least as strong as a single direct call —
/// escalation can only add budget, never take it away. `Timeout` and
/// `Panic` are terminal: a wall-clock deadline re-runs into the same wall,
/// and a crash wants a bug report, not a hotter retry.
#[derive(Debug, Clone)]
pub struct EscalationPolicy {
    /// Total attempts (≥ 1); the last runs at `max(initial·growthⁿ, base)`.
    pub max_attempts: usize,
    /// Budget for attempt 0.
    pub initial: SaturationLimits,
    /// Per-attempt multiplier on `max_iters`.
    pub iters_factor: usize,
    /// Per-attempt multiplier on `max_nodes`.
    pub nodes_factor: usize,
}

impl Default for EscalationPolicy {
    fn default() -> Self {
        EscalationPolicy {
            max_attempts: 3,
            initial: SaturationLimits::new(4, 15_000),
            iters_factor: 2,
            nodes_factor: 4,
        }
    }
}

impl EscalationPolicy {
    /// A degenerate policy: one attempt at exactly the base limits (the
    /// zero `initial` is always raised to the base by the final-attempt
    /// floor in [`EscalationPolicy::limits_for`]).
    pub fn single_shot() -> Self {
        EscalationPolicy {
            max_attempts: 1,
            initial: SaturationLimits::new(0, 0),
            ..Default::default()
        }
    }

    /// Limits for `attempt` (0-based) against the caller's `base` limits.
    pub fn limits_for(&self, attempt: usize, base: SaturationLimits) -> SaturationLimits {
        let mut l = self.initial;
        for _ in 0..attempt {
            l.max_iters = l.max_iters.saturating_mul(self.iters_factor.max(1));
            l.max_nodes = l.max_nodes.saturating_mul(self.nodes_factor.max(1));
        }
        if attempt + 1 >= self.max_attempts {
            l.max_iters = l.max_iters.max(base.max_iters);
            l.max_nodes = l.max_nodes.max(base.max_nodes);
        }
        l.deadline = base.deadline;
        l
    }
}

/// Deprecated wrapper over [`crate::verifier::Verifier`] with an
/// escalation policy: panic-isolated inference under iterative deepening.
#[deprecated(
    since = "0.1.0",
    note = "use graphguard::verifier::Verifier::with_config(cfg).escalation(policy)\
            .run_counted(gs, gd, ri) (migration table in EXPERIMENTS.md §Serve)"
)]
pub fn check_refinement_escalating(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
    policy: &EscalationPolicy,
) -> (Verdict, usize) {
    crate::verifier::Verifier::with_config(cfg.clone())
        .escalation(policy.clone())
        .run_counted(gs, gd, ri)
}

/// Panic-isolated inference under an escalation policy. Returns the final
/// verdict and the number of attempts spent (≥ 1). Escalation implies
/// isolation: every attempt runs through [`isolated_core`].
pub(crate) fn escalating_core(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
    policy: &EscalationPolicy,
) -> (Verdict, usize) {
    let attempts = policy.max_attempts.max(1);
    for attempt in 0..attempts {
        let last = attempt + 1 >= attempts;
        let mut c = cfg.clone();
        c.limits = policy.limits_for(attempt, cfg.limits);
        let v = isolated_core(gs, gd, ri, &c);
        let retry = match &v {
            Verdict::Verified(_) => false,
            // A fixpoint refutation is budget-independent; only an
            // unsaturated one can flip with more budget.
            Verdict::Refuted(e) => e.unsaturated,
            Verdict::Inconclusive(i) => i.reason == InconclusiveReason::NodeBudget,
        };
        if last || !retry {
            return (v, attempt + 1);
        }
    }
    unreachable!("loop returns on its final attempt")
}

/// Listing 2 + Listing 3: clean output relation for one operator.
#[allow(clippy::too_many_arguments)]
fn compute_node_out_rel(
    nid: NodeId,
    gs: &Graph,
    gd: &Graph,
    r: &Relation,
    rules: &[crate::egraph::Rewrite],
    ctx: &RewriteCtx,
    cfg: &InferConfig,
    limits: SaturationLimits,
    eg: &mut EGraph,
    stats: &mut SatStats,
) -> Result<(Vec<CleanCand>, NodeTiming), RefinementError> {
    let node = gs.node(nid);
    let mk_err = |frontier: usize, explored: usize| RefinementError {
        node: nid,
        node_name: node.name.clone(),
        op: format!("{}", node.op),
        inputs: node
            .inputs
            .iter()
            .map(|&t| {
                let cands = r.get(t);
                let sample = cands.first().map(|c| {
                    crate::expr::print::render(
                        &c.expr,
                        &crate::expr::print::Namer { gs, gd },
                    )
                });
                (gs.tensor(t).name.clone(), cands.len(), sample)
            })
            .collect(),
        frontier_size: frontier,
        explored_gd_nodes: explored,
        unsaturated: false,
    };

    // -- Step 1 (Listing 2): seed the e-graph with v(I(v)) and the input
    //    relation. Leaf classes for G_s inputs are unioned with each of
    //    their G_d mapping expressions; the e-graph's congruence does the
    //    all-combinations substitution of rewrite_t_to_expr for us. The
    //    arena is pooled across operators — reset, not reallocated.
    eg.reset();
    let gd_leaf_shape = |t: TensorRef| -> Option<Vec<i64>> {
        (t.side == Side::D).then(|| gd.shape(t.id).to_vec())
    };
    let mut t_rel: FxHashSet<TensorId> = FxHashSet::default();
    let mut input_classes = Vec::with_capacity(node.inputs.len());
    for &t in &node.inputs {
        let leaf = eg.add_leaf(TensorRef::s(t), gs.shape(t).to_vec());
        let cands = r.get(t);
        if cands.is_empty() {
            return Err(mk_err(0, 0));
        }
        for cand in cands {
            let Ok(root) = eg.add_expr(&cand.expr, &gd_leaf_shape) else { continue };
            let _ = eg.union(leaf, root);
            for &l in &cand.leaves {
                t_rel.insert(l.id);
            }
        }
        input_classes.push(leaf);
    }
    let target = match eg.add_op(node.op.clone(), input_classes) {
        Ok(id) => id,
        Err(_) => return Err(mk_err(t_rel.len(), 0)),
    };
    eg.rebuild();

    // -- Step 2: saturate with lemmas.
    let s = saturate(eg, rules, ctx, limits);
    stats.merge(&s);
    if s.exhausted == Some(Exhaustion::Deadline) {
        // The deadline is authoritative: no extraction on the partial
        // e-graph, the region is abandoned as-is (→ Inconclusive upstream).
        return Err(mk_err(t_rel.len(), 0));
    }
    let mut node_budget_hit = s.exhausted == Some(Exhaustion::NodeBudget);

    // -- Step 3 (Listing 3): frontier exploration of G_d. Add definitional
    //    equalities t_d ≡ op(inputs) for G_d nodes all of whose inputs are
    //    in T_rel; saturate; extract; grow T_rel from clean candidates.
    let mut explored: FxHashSet<NodeId> = FxHashSet::default();
    let mut best: Vec<CleanCand> = Vec::new();
    let mut converged = false;
    for _iter in 0..cfg.max_frontier_iters {
        let mut added = false;
        if !node_budget_hit {
            for dnid in gd.topo_order() {
                if explored.contains(&dnid) {
                    continue;
                }
                let dnode = gd.node(dnid);
                if !dnode.inputs.iter().all(|t| t_rel.contains(t)) {
                    continue;
                }
                explored.insert(dnid);
                added = true;
                let children: Vec<Id> = dnode
                    .inputs
                    .iter()
                    .map(|&t| eg.add_leaf(TensorRef::d(t), gd.shape(t).to_vec()))
                    .collect();
                let out_leaf =
                    eg.add_leaf(TensorRef::d(dnode.output), gd.shape(dnode.output).to_vec());
                if let Ok(def) = eg.add_op(dnode.op.clone(), children) {
                    let _ = eg.union(out_leaf, def);
                }
                // Forward closure: an explored node's output is related to v's
                // inputs, so its consumers satisfy observation (i)/(ii) of
                // §4.3.1. (Slightly broader than Listing 3's clean-expression
                // growth — same exclusion of unrelated tensors, see DESIGN.md.)
                t_rel.insert(dnode.output);
            }
        }
        if added {
            eg.rebuild();
            let s = saturate(eg, rules, ctx, limits);
            stats.merge(&s);
            if s.exhausted == Some(Exhaustion::Deadline) {
                return Err(mk_err(t_rel.len(), explored.len()));
            }
            node_budget_hit |= s.exhausted == Some(Exhaustion::NodeBudget);
        }

        // extract clean candidates for the target class over D-side leaves.
        // A node-budget abort still extracts: equalities found before the
        // cap are valid, and a mapping among them is a real proof.
        let cands = extract_clean(eg, &|t| t.side == Side::D);
        let mut grew = false;
        if let Some(target_cands) = cands.get(&eg.find(target)) {
            best = target_cands.clone();
            for c in target_cands {
                for &l in &c.leaves {
                    grew |= t_rel.insert(l.id);
                }
            }
        }
        if node_budget_hit {
            // Further frontier growth would only re-trip the cap; keep
            // whatever extraction produced.
            break;
        }
        if !added && !grew {
            converged = true;
            break;
        }
    }
    if !converged && !node_budget_hit {
        // Frontier loop stopped on its iteration cap while still growing.
        stats.saturated = false;
    }

    let timing = NodeTiming {
        node_name: String::new(),
        micros: 0,
        egraph_nodes: eg.n_nodes,
        explored_gd: explored.len(),
    };
    if best.is_empty() {
        return Err(mk_err(t_rel.len(), explored.len()));
    }
    Ok((best, timing))
}

/// Numeric soundness certificate: draw random `G_d` inputs, derive `G_s`
/// inputs via `R_i`, run both graphs, and check every `R_o` mapping
/// reconstructs the `G_s` output (§3.3 "acts as a certificate").
pub fn verify_numeric(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    ro: &Relation,
    seed: u64,
) -> Result<()> {
    use crate::expr::eval::{eval_expr, eval_graph, random_inputs, Env};
    let gd_inputs = random_inputs(gd, seed);
    // env over G_d leaves for evaluating relation expressions
    let mut env: Env = Env::default();
    for (&t, v) in &gd_inputs {
        env.insert(TensorRef::d(t), v.clone());
    }
    // derive G_s inputs from R_i
    let mut gs_inputs: FxHashMap<TensorId, crate::util::ndarray::NdArray> = FxHashMap::default();
    for &i in &gs.inputs {
        let cands = ri.get(i);
        let cand = cands
            .first()
            .ok_or_else(|| anyhow::anyhow!("R_i misses input '{}'", gs.tensor(i).name))?;
        gs_inputs.insert(i, eval_expr(&cand.expr, &env)?);
        // all R_i mappings for the same input must agree (replication check)
        for other in &cands[1..] {
            let v = eval_expr(&other.expr, &env)?;
            anyhow::ensure!(
                v.allclose(&gs_inputs[&i], 1e-4, 1e-5),
                "inconsistent R_i mappings for '{}'",
                gs.tensor(i).name
            );
        }
    }
    let gs_vals = eval_graph(gs, &gs_inputs)?;
    let gd_vals = eval_graph(gd, &gd_inputs)?;
    let mut full_env: Env = Env::default();
    for (t, v) in gd_vals.iter().enumerate() {
        full_env.insert(TensorRef::d(t as TensorId), v.clone());
    }
    for &o in &gs.outputs {
        let cands = ro.get(o);
        anyhow::ensure!(!cands.is_empty(), "R_o misses output '{}'", gs.tensor(o).name);
        for cand in cands {
            let rebuilt = eval_expr(&cand.expr, &full_env)?;
            anyhow::ensure!(
                rebuilt.allclose(&gs_vals[o as usize], 2e-3, 1e-4),
                "R_o mapping for '{}' does not reconstruct the output (|Δ|={})",
                gs.tensor(o).name,
                rebuilt.max_abs_diff(&gs_vals[o as usize])
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;
    use crate::util::json::Json;
    use crate::verifier::Verifier;

    /// Figure 1/2 running example: G_s = matsub(matmul(A,B), E);
    /// G_d = TP over the inner dim with reduce-scatter + all-gather.
    fn running_example() -> (Graph, Graph, Relation) {
        let mut gs = Graph::new("fig1_gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let e = gs.input("E", vec![4, 4]);
        let c = gs.matmul("C", a, b);
        let f = gs.sub2("F", c, e);
        gs.mark_output(f);

        let mut gd = Graph::new("fig1_gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let b2 = gd.input("B_2", vec![3, 4]);
        let e1 = gd.input("E_1", vec![2, 4]);
        let e2 = gd.input("E_2", vec![2, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        let c2 = gd.matmul("C_2", a2, b2);
        // reduce-scatter row chunks of the partial sums
        let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
        let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
        let f1 = gd.sub2("F_1", d1, e1);
        let f2 = gd.sub2("F_2", d2, e2);
        let f = gd.all_gather("F_full", vec![f1, f2], 0);
        gd.mark_output(f);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{
                "A": ["concat(A_1, A_2; dim=1)"],
                "B": ["concat(B_1, B_2; dim=0)"],
                "E": ["concat(E_1, E_2; dim=0)"]
            }"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        (gs, gd, ri)
    }

    #[test]
    fn running_example_refines() {
        let (gs, gd, ri) = running_example();
        let out = Verifier::new().expect(&gs, &gd, &ri).unwrap_or_else(|e| panic!("{e}"));
        let f = gs.tensor_by_name("F").unwrap();
        assert!(out.relation.contains(f), "F must be mapped");
        // the O(G_d)-only mapping should be the gathered output itself
        let namer = crate::expr::print::Namer { gs: &gs, gd: &gd };
        let rendered: Vec<String> = out
            .relation
            .get(f)
            .iter()
            .map(|c| crate::expr::print::render(&c.expr, &namer))
            .collect();
        assert!(
            rendered.iter().any(|s| s.contains("F_full")),
            "expected mapping via F_full, got {rendered:?}"
        );
        // intermediate C maps both as a shard-sum and via reduce-scatter
        let c = gs.tensor_by_name("C").unwrap();
        assert!(out.relation_full.contains(c));
        // numeric certificate
        verify_numeric(&gs, &gd, &ri, &out.relation, 42).unwrap();
    }

    #[test]
    fn missing_computation_is_detected() {
        // G_d that computes only the diagonal blocks (bug 4 flavor): the
        // matmul output cannot be reconstructed.
        let mut gs = Graph::new("gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let c = gs.matmul("C", a, b);
        gs.mark_output(c);

        let mut gd = Graph::new("gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let _b2 = gd.input("B_2", vec![3, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        // BUG: second partial product never computed; C_2 reuses C_1's B
        let c2 = gd.matmul("C_2", a2, b1);
        let f = gd.all_reduce("C_sum", vec![c1, c2]);
        gd.mark_output(f);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{"A": ["concat(A_1, A_2; dim=1)"], "B": ["concat(B_1, B_2; dim=0)"]}"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let err = Verifier::new().expect(&gs, &gd, &ri).unwrap_err();
        assert_eq!(err.node_name, "C", "error localizes the matmul");
        let msg = format!("{err}");
        assert!(msg.contains("refinement FAILED"), "{msg}");
    }

    #[test]
    fn replicated_computation_maps_directly() {
        // G_d replicates the whole computation on 2 ranks; outputs map as
        // plain leaves.
        let mut gs = Graph::new("gs");
        let x = gs.input("X", vec![4, 4]);
        let y = gs.op("Y", Op::Gelu, vec![x]);
        gs.mark_output(y);

        let mut gd = Graph::new("gd");
        let x0 = gd.input("X_0", vec![4, 4]);
        let y0 = gd.op("Y_0", Op::Gelu, vec![x0]);
        gd.mark_output(y0);

        let ri = Relation::from_json(
            &Json::parse(r#"{"X": ["X_0"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let out = Verifier::new().expect(&gs, &gd, &ri).unwrap();
        let y_id = gs.tensor_by_name("Y").unwrap();
        assert_eq!(out.relation.get(y_id)[0].cost, 0, "direct leaf mapping");
        verify_numeric(&gs, &gd, &ri, &out.relation, 7).unwrap();
    }

    #[test]
    fn frontier_excludes_unrelated_tensors() {
        // E_i feed a side computation unrelated to the matmul being
        // processed; Listing 3's frontier must not pull them in.
        let (gs, gd, ri) = running_example();
        let mut stats = SatStats { saturated: true, ..Default::default() };
        let rules = lemmas::standard_rewrites();
        let ctx = RewriteCtx::default();
        let cfg = InferConfig::default();
        let mut scratch = EGraph::new();
        // node 0 in gs is the matmul
        let (cands, timing) = compute_node_out_rel(
            0, &gs, &gd, &ri, &rules, &ctx, &cfg, cfg.limits, &mut scratch, &mut stats,
        )
        .unwrap();
        assert!(!cands.is_empty());
        // explored G_d nodes: C_1, C_2, D_1, D_2 — but not F_1/F_2 (need E)
        assert!(
            timing.explored_gd <= 4,
            "frontier exploration leaked to unrelated nodes: {}",
            timing.explored_gd
        );
    }

    #[test]
    fn per_node_timings_recorded() {
        let (gs, gd, ri) = running_example();
        let out = Verifier::new().expect(&gs, &gd, &ri).unwrap();
        assert_eq!(out.per_node.len(), gs.num_nodes());
        assert!(out.stats.total_applications() > 0, "lemmas were applied");
    }

    // ---- three-valued verdicts (resource budgets never read as bugs) ----

    #[test]
    fn node_budget_on_clean_pair_is_inconclusive_not_refuted() {
        let (gs, gd, ri) = running_example();
        let cfg = InferConfig {
            limits: SaturationLimits::new(8, 10),
            ..InferConfig::default()
        };
        match Verifier::with_config(cfg).run(&gs, &gd, &ri) {
            Verdict::Inconclusive(i) => {
                assert_eq!(i.reason, InconclusiveReason::NodeBudget);
                assert!(!i.region.is_empty());
            }
            v => panic!("starved clean pair must be inconclusive, got {}", v.tag()),
        }
    }

    #[test]
    fn elapsed_deadline_on_clean_pair_is_inconclusive_timeout() {
        let (gs, gd, ri) = running_example();
        let cfg = InferConfig {
            region_deadline: Some(Duration::ZERO),
            ..InferConfig::default()
        };
        match Verifier::with_config(cfg).run(&gs, &gd, &ri) {
            Verdict::Inconclusive(i) => assert_eq!(i.reason, InconclusiveReason::Timeout),
            v => panic!("zero deadline must be inconclusive, got {}", v.tag()),
        }
    }

    #[test]
    fn genuine_refutation_survives_verdict_layer() {
        // same graphs as missing_computation_is_detected, via the verdict API
        let mut gs = Graph::new("gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let c = gs.matmul("C", a, b);
        gs.mark_output(c);
        let mut gd = Graph::new("gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let _b2 = gd.input("B_2", vec![3, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        let c2 = gd.matmul("C_2", a2, b1);
        let f = gd.all_reduce("C_sum", vec![c1, c2]);
        gd.mark_output(f);
        let ri = Relation::from_json(
            &Json::parse(
                r#"{"A": ["concat(A_1, A_2; dim=1)"], "B": ["concat(B_1, B_2; dim=0)"]}"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        match Verifier::new().run(&gs, &gd, &ri) {
            Verdict::Refuted(e) => assert_eq!(e.node_name, "C"),
            v => panic!("genuine bug must stay refuted, got {}", v.tag()),
        }
    }

    #[test]
    fn escalation_recovers_clean_pair_from_starved_initial_budget() {
        let (gs, gd, ri) = running_example();
        let policy = EscalationPolicy {
            max_attempts: 3,
            initial: SaturationLimits::new(8, 10),
            iters_factor: 2,
            nodes_factor: 4,
        };
        let (v, attempts) = Verifier::new().escalation(policy).run_counted(&gs, &gd, &ri);
        assert!(v.is_verified(), "final attempt runs at >= base budget; got {}", v.tag());
        assert!(attempts > 1, "tiny initial budget must have been escalated");
    }

    #[test]
    fn escalation_final_attempt_never_below_base() {
        let policy = EscalationPolicy::default();
        let base = SaturationLimits::new(8, 60_000);
        let l = policy.limits_for(policy.max_attempts - 1, base);
        assert!(l.max_iters >= base.max_iters && l.max_nodes >= base.max_nodes);
        // first attempt is genuinely smaller (the fast path)
        let l0 = policy.limits_for(0, base);
        assert!(l0.max_nodes < base.max_nodes);
    }

    #[test]
    #[allow(deprecated)] // pins the deprecated shim's contract on purpose
    fn two_valued_wrapper_refuses_to_misreport_inconclusive() {
        // The compat wrapper (and Verifier::expect underneath it) must panic
        // loudly on Inconclusive rather than fold it into Ok (false proof)
        // or Err (false alarm). Applier-panic isolation end-to-end is
        // exercised in tests/chaos.rs.
        let (gs, gd, ri) = running_example();
        let cfg =
            InferConfig { limits: SaturationLimits::new(8, 10), ..InferConfig::default() };
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // keep the expected panic quiet
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check_refinement(&gs, &gd, &ri, &cfg)
        }));
        std::panic::set_hook(prev);
        assert!(r.is_err(), "wrapper must refuse the two-valued lie");
    }
}
