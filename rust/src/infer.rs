//! The iterative relation-inference algorithm — the paper's core
//! contribution (Listings 1–3).
//!
//! [`check_refinement`] walks `G_s` in topological order (Listing 1). For
//! each operator it builds a *fresh, small* e-graph seeded with the
//! operator's expression over already-mapped inputs, saturates it against
//! the lemma library, then iteratively unions in `G_d` definitional
//! equalities restricted to the `T_rel` frontier (Listing 3) and extracts
//! clean candidate mappings for the operator's output (Listing 2). A node
//! with no clean mapping aborts with a [`RefinementError`] naming the
//! operator — the paper's bug-localization output (§6.2).

use crate::egraph::{
    extract_clean, saturate, CleanCand, EGraph, Id, RewriteCtx, SatStats, SaturationLimits,
};
use crate::expr::{Side, TensorRef};
use crate::ir::{Graph, NodeId, TensorId};
use crate::lemmas;
use crate::relation::Relation;
use anyhow::Result;
use rustc_hash::{FxHashMap, FxHashSet};
use std::fmt;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct InferConfig {
    pub limits: SaturationLimits,
    /// Max frontier-expansion iterations per operator (Listing 3 loop).
    pub max_frontier_iters: usize,
    /// Numerically re-check the final `R_o` on random inputs (soundness
    /// certificate). Costs one evaluation of both graphs.
    pub check_numeric: bool,
    /// Pipeline channels whose buffer slot failed the schedule's liveness
    /// audit (`schedule::quarantined_channels`): `recv_of_send_identity`
    /// refuses to collapse them even when the tags match. Empty by default.
    pub quarantined_channels: Vec<usize>,
}

impl Default for InferConfig {
    fn default() -> Self {
        InferConfig {
            limits: SaturationLimits { max_iters: 8, max_nodes: 60_000 },
            max_frontier_iters: 12,
            check_numeric: false,
            quarantined_channels: Vec::new(),
        }
    }
}

/// Refinement failure: the operator whose outputs could not be mapped,
/// plus the context a user needs to localize the bug (§6.2).
#[derive(Debug, Clone)]
pub struct RefinementError {
    pub node: NodeId,
    pub node_name: String,
    pub op: String,
    /// For each input: (tensor name, #mappings available, sample mapping).
    pub inputs: Vec<(String, usize, Option<String>)>,
    pub frontier_size: usize,
    pub explored_gd_nodes: usize,
}

impl fmt::Display for RefinementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "refinement FAILED at operator '{}' ({}): no clean mapping for its output",
            self.node_name, self.op
        )?;
        writeln!(f, "  input relations at this operator:")?;
        for (name, n, sample) in &self.inputs {
            match sample {
                Some(s) => writeln!(f, "    {name}: {n} mapping(s), e.g. {s}")?,
                None => writeln!(f, "    {name}: NO mapping — trace the producing operator")?,
            }
        }
        write!(
            f,
            "  explored {} G_d operators over a frontier of {} related tensors;\n  \
             inspect this operator and the G_d subgraph that should compute it",
            self.explored_gd_nodes, self.frontier_size
        )
    }
}

impl std::error::Error for RefinementError {}

#[derive(Debug, Clone, Default)]
pub struct NodeTiming {
    pub node_name: String,
    pub micros: u64,
    pub egraph_nodes: usize,
    pub explored_gd: usize,
}

/// Successful inference output.
#[derive(Debug)]
pub struct InferOutput {
    /// Complete clean output relation `R_o` (restricted to `O(G_s)`; leaves
    /// restricted to `O(G_d)` where possible — see `relation_full`).
    pub relation: Relation,
    /// Mappings for every `G_s` tensor (debugging, bug-5-style inspection).
    pub relation_full: Relation,
    /// Aggregated lemma-application counts (Figure 7 raw data).
    pub stats: SatStats,
    pub per_node: Vec<NodeTiming>,
}

/// Listing 1: compute the output relation, iterating operators of `G_s`.
pub fn check_refinement(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    cfg: &InferConfig,
) -> Result<InferOutput, RefinementError> {
    let rules = lemmas::standard_rewrites();
    let mut ctx = RewriteCtx::default();
    ctx.quarantine_channels(cfg.quarantined_channels.iter().copied());
    let mut r = ri.clone();
    let mut stats = SatStats { saturated: true, ..Default::default() };
    let mut per_node = Vec::with_capacity(gs.num_nodes());
    // One e-graph arena reused (via `reset`) across the whole topological
    // walk: per-operator e-graphs are small but numerous, so keeping the
    // memo-table / class-map / union-find allocations warm is a measurable
    // win on many-operator models (see EXPERIMENTS.md §Perf).
    let mut scratch = EGraph::new();

    for nid in gs.topo_order() {
        let t0 = Instant::now();
        let node = gs.node(nid);
        let out =
            compute_node_out_rel(nid, gs, gd, &r, &rules, &ctx, cfg, &mut scratch, &mut stats);
        match out {
            Ok((cands, timing)) => {
                per_node.push(NodeTiming {
                    node_name: node.name.clone(),
                    micros: t0.elapsed().as_micros() as u64,
                    ..timing
                });
                r.insert_all(node.output, cands);
            }
            Err(mut e) => {
                e.node = nid;
                return Err(e);
            }
        }
    }

    // Listing 1 line 9: restrict to O(G_s) with leaves in O(G_d). An output
    // with no such expression means G_d's outputs cannot reconstruct it —
    // an incomplete R_o, i.e. a bug (§3.1), reported against the producing
    // operator.
    let out_ok = |t: TensorRef| t.side == Side::D && gd.is_output(t.id);
    let ro = r.restrict(&gs.outputs, out_ok);
    for &o in &gs.outputs {
        if !ro.contains(o) {
            let node = gs
                .producer(o)
                .map(|n| n.name.clone())
                .unwrap_or_else(|| gs.tensor(o).name.clone());
            let nid = gs
                .topo_order()
                .find(|&n| gs.node(n).output == o)
                .unwrap_or(0);
            return Err(RefinementError {
                node: nid,
                node_name: node,
                op: "output filter".into(),
                inputs: vec![(
                    gs.tensor(o).name.clone(),
                    r.get(o).len(),
                    r.get(o).first().map(|c| {
                        crate::expr::print::render(
                            &c.expr,
                            &crate::expr::print::Namer { gs, gd },
                        )
                    }),
                )],
                frontier_size: 0,
                explored_gd_nodes: 0,
            });
        }
    }
    Ok(InferOutput { relation: ro, relation_full: r, stats, per_node })
}

/// Listing 2 + Listing 3: clean output relation for one operator.
#[allow(clippy::too_many_arguments)]
fn compute_node_out_rel(
    nid: NodeId,
    gs: &Graph,
    gd: &Graph,
    r: &Relation,
    rules: &[crate::egraph::Rewrite],
    ctx: &RewriteCtx,
    cfg: &InferConfig,
    eg: &mut EGraph,
    stats: &mut SatStats,
) -> Result<(Vec<CleanCand>, NodeTiming), RefinementError> {
    let node = gs.node(nid);
    let mk_err = |frontier: usize, explored: usize| RefinementError {
        node: nid,
        node_name: node.name.clone(),
        op: format!("{}", node.op),
        inputs: node
            .inputs
            .iter()
            .map(|&t| {
                let cands = r.get(t);
                let sample = cands.first().map(|c| {
                    crate::expr::print::render(
                        &c.expr,
                        &crate::expr::print::Namer { gs, gd },
                    )
                });
                (gs.tensor(t).name.clone(), cands.len(), sample)
            })
            .collect(),
        frontier_size: frontier,
        explored_gd_nodes: explored,
    };

    // -- Step 1 (Listing 2): seed the e-graph with v(I(v)) and the input
    //    relation. Leaf classes for G_s inputs are unioned with each of
    //    their G_d mapping expressions; the e-graph's congruence does the
    //    all-combinations substitution of rewrite_t_to_expr for us. The
    //    arena is pooled across operators — reset, not reallocated.
    eg.reset();
    let gd_leaf_shape = |t: TensorRef| -> Option<Vec<i64>> {
        (t.side == Side::D).then(|| gd.shape(t.id).to_vec())
    };
    let mut t_rel: FxHashSet<TensorId> = FxHashSet::default();
    let mut input_classes = Vec::with_capacity(node.inputs.len());
    for &t in &node.inputs {
        let leaf = eg.add_leaf(TensorRef::s(t), gs.shape(t).to_vec());
        let cands = r.get(t);
        if cands.is_empty() {
            return Err(mk_err(0, 0));
        }
        for cand in cands {
            let Ok(root) = eg.add_expr(&cand.expr, &gd_leaf_shape) else { continue };
            let _ = eg.union(leaf, root);
            for &l in &cand.leaves {
                t_rel.insert(l.id);
            }
        }
        input_classes.push(leaf);
    }
    let target = match eg.add_op(node.op.clone(), input_classes) {
        Ok(id) => id,
        Err(_) => return Err(mk_err(t_rel.len(), 0)),
    };
    eg.rebuild();

    // -- Step 2: saturate with lemmas.
    let s = saturate(eg, rules, ctx, cfg.limits);
    stats.merge(&s);

    // -- Step 3 (Listing 3): frontier exploration of G_d. Add definitional
    //    equalities t_d ≡ op(inputs) for G_d nodes all of whose inputs are
    //    in T_rel; saturate; extract; grow T_rel from clean candidates.
    let mut explored: FxHashSet<NodeId> = FxHashSet::default();
    let mut best: Vec<CleanCand> = Vec::new();
    for _iter in 0..cfg.max_frontier_iters {
        let mut added = false;
        for dnid in gd.topo_order() {
            if explored.contains(&dnid) {
                continue;
            }
            let dnode = gd.node(dnid);
            if !dnode.inputs.iter().all(|t| t_rel.contains(t)) {
                continue;
            }
            explored.insert(dnid);
            added = true;
            let children: Vec<Id> = dnode
                .inputs
                .iter()
                .map(|&t| eg.add_leaf(TensorRef::d(t), gd.shape(t).to_vec()))
                .collect();
            let out_leaf = eg.add_leaf(TensorRef::d(dnode.output), gd.shape(dnode.output).to_vec());
            if let Ok(def) = eg.add_op(dnode.op.clone(), children) {
                let _ = eg.union(out_leaf, def);
            }
            // Forward closure: an explored node's output is related to v's
            // inputs, so its consumers satisfy observation (i)/(ii) of
            // §4.3.1. (Slightly broader than Listing 3's clean-expression
            // growth — same exclusion of unrelated tensors, see DESIGN.md.)
            t_rel.insert(dnode.output);
        }
        if added {
            eg.rebuild();
            let s = saturate(eg, rules, ctx, cfg.limits);
            stats.merge(&s);
        }

        // extract clean candidates for the target class over D-side leaves
        let cands = extract_clean(eg, &|t| t.side == Side::D);
        let mut grew = false;
        if let Some(target_cands) = cands.get(&eg.find(target)) {
            best = target_cands.clone();
            for c in target_cands {
                for &l in &c.leaves {
                    grew |= t_rel.insert(l.id);
                }
            }
        }
        if !added && !grew {
            break;
        }
    }

    let timing =
        NodeTiming { node_name: String::new(), micros: 0, egraph_nodes: eg.n_nodes, explored_gd: explored.len() };
    if best.is_empty() {
        return Err(mk_err(t_rel.len(), explored.len()));
    }
    Ok((best, timing))
}

/// Numeric soundness certificate: draw random `G_d` inputs, derive `G_s`
/// inputs via `R_i`, run both graphs, and check every `R_o` mapping
/// reconstructs the `G_s` output (§3.3 "acts as a certificate").
pub fn verify_numeric(
    gs: &Graph,
    gd: &Graph,
    ri: &Relation,
    ro: &Relation,
    seed: u64,
) -> Result<()> {
    use crate::expr::eval::{eval_expr, eval_graph, random_inputs, Env};
    let gd_inputs = random_inputs(gd, seed);
    // env over G_d leaves for evaluating relation expressions
    let mut env: Env = Env::default();
    for (&t, v) in &gd_inputs {
        env.insert(TensorRef::d(t), v.clone());
    }
    // derive G_s inputs from R_i
    let mut gs_inputs: FxHashMap<TensorId, crate::util::ndarray::NdArray> = FxHashMap::default();
    for &i in &gs.inputs {
        let cands = ri.get(i);
        let cand = cands
            .first()
            .ok_or_else(|| anyhow::anyhow!("R_i misses input '{}'", gs.tensor(i).name))?;
        gs_inputs.insert(i, eval_expr(&cand.expr, &env)?);
        // all R_i mappings for the same input must agree (replication check)
        for other in &cands[1..] {
            let v = eval_expr(&other.expr, &env)?;
            anyhow::ensure!(
                v.allclose(&gs_inputs[&i], 1e-4, 1e-5),
                "inconsistent R_i mappings for '{}'",
                gs.tensor(i).name
            );
        }
    }
    let gs_vals = eval_graph(gs, &gs_inputs)?;
    let gd_vals = eval_graph(gd, &gd_inputs)?;
    let mut full_env: Env = Env::default();
    for (t, v) in gd_vals.iter().enumerate() {
        full_env.insert(TensorRef::d(t as TensorId), v.clone());
    }
    for &o in &gs.outputs {
        let cands = ro.get(o);
        anyhow::ensure!(!cands.is_empty(), "R_o misses output '{}'", gs.tensor(o).name);
        for cand in cands {
            let rebuilt = eval_expr(&cand.expr, &full_env)?;
            anyhow::ensure!(
                rebuilt.allclose(&gs_vals[o as usize], 2e-3, 1e-4),
                "R_o mapping for '{}' does not reconstruct the output (|Δ|={})",
                gs.tensor(o).name,
                rebuilt.max_abs_diff(&gs_vals[o as usize])
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;
    use crate::util::json::Json;

    /// Figure 1/2 running example: G_s = matsub(matmul(A,B), E);
    /// G_d = TP over the inner dim with reduce-scatter + all-gather.
    fn running_example() -> (Graph, Graph, Relation) {
        let mut gs = Graph::new("fig1_gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let e = gs.input("E", vec![4, 4]);
        let c = gs.matmul("C", a, b);
        let f = gs.sub2("F", c, e);
        gs.mark_output(f);

        let mut gd = Graph::new("fig1_gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let b2 = gd.input("B_2", vec![3, 4]);
        let e1 = gd.input("E_1", vec![2, 4]);
        let e2 = gd.input("E_2", vec![2, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        let c2 = gd.matmul("C_2", a2, b2);
        // reduce-scatter row chunks of the partial sums
        let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
        let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
        let f1 = gd.sub2("F_1", d1, e1);
        let f2 = gd.sub2("F_2", d2, e2);
        let f = gd.all_gather("F_full", vec![f1, f2], 0);
        gd.mark_output(f);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{
                "A": ["concat(A_1, A_2; dim=1)"],
                "B": ["concat(B_1, B_2; dim=0)"],
                "E": ["concat(E_1, E_2; dim=0)"]
            }"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        (gs, gd, ri)
    }

    #[test]
    fn running_example_refines() {
        let (gs, gd, ri) = running_example();
        let out = check_refinement(&gs, &gd, &ri, &InferConfig::default())
            .unwrap_or_else(|e| panic!("{e}"));
        let f = gs.tensor_by_name("F").unwrap();
        assert!(out.relation.contains(f), "F must be mapped");
        // the O(G_d)-only mapping should be the gathered output itself
        let namer = crate::expr::print::Namer { gs: &gs, gd: &gd };
        let rendered: Vec<String> = out
            .relation
            .get(f)
            .iter()
            .map(|c| crate::expr::print::render(&c.expr, &namer))
            .collect();
        assert!(
            rendered.iter().any(|s| s.contains("F_full")),
            "expected mapping via F_full, got {rendered:?}"
        );
        // intermediate C maps both as a shard-sum and via reduce-scatter
        let c = gs.tensor_by_name("C").unwrap();
        assert!(out.relation_full.contains(c));
        // numeric certificate
        verify_numeric(&gs, &gd, &ri, &out.relation, 42).unwrap();
    }

    #[test]
    fn missing_computation_is_detected() {
        // G_d that computes only the diagonal blocks (bug 4 flavor): the
        // matmul output cannot be reconstructed.
        let mut gs = Graph::new("gs");
        let a = gs.input("A", vec![4, 6]);
        let b = gs.input("B", vec![6, 4]);
        let c = gs.matmul("C", a, b);
        gs.mark_output(c);

        let mut gd = Graph::new("gd");
        let a1 = gd.input("A_1", vec![4, 3]);
        let a2 = gd.input("A_2", vec![4, 3]);
        let b1 = gd.input("B_1", vec![3, 4]);
        let _b2 = gd.input("B_2", vec![3, 4]);
        let c1 = gd.matmul("C_1", a1, b1);
        // BUG: second partial product never computed; C_2 reuses C_1's B
        let c2 = gd.matmul("C_2", a2, b1);
        let f = gd.all_reduce("C_sum", vec![c1, c2]);
        gd.mark_output(f);

        let ri = Relation::from_json(
            &Json::parse(
                r#"{"A": ["concat(A_1, A_2; dim=1)"], "B": ["concat(B_1, B_2; dim=0)"]}"#,
            )
            .unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let err = check_refinement(&gs, &gd, &ri, &InferConfig::default()).unwrap_err();
        assert_eq!(err.node_name, "C", "error localizes the matmul");
        let msg = format!("{err}");
        assert!(msg.contains("refinement FAILED"), "{msg}");
    }

    #[test]
    fn replicated_computation_maps_directly() {
        // G_d replicates the whole computation on 2 ranks; outputs map as
        // plain leaves.
        let mut gs = Graph::new("gs");
        let x = gs.input("X", vec![4, 4]);
        let y = gs.op("Y", Op::Gelu, vec![x]);
        gs.mark_output(y);

        let mut gd = Graph::new("gd");
        let x0 = gd.input("X_0", vec![4, 4]);
        let y0 = gd.op("Y_0", Op::Gelu, vec![x0]);
        gd.mark_output(y0);

        let ri = Relation::from_json(
            &Json::parse(r#"{"X": ["X_0"]}"#).unwrap(),
            &gs,
            &gd,
        )
        .unwrap();
        let out = check_refinement(&gs, &gd, &ri, &InferConfig::default()).unwrap();
        let y_id = gs.tensor_by_name("Y").unwrap();
        assert_eq!(out.relation.get(y_id)[0].cost, 0, "direct leaf mapping");
        verify_numeric(&gs, &gd, &ri, &out.relation, 7).unwrap();
    }

    #[test]
    fn frontier_excludes_unrelated_tensors() {
        // E_i feed a side computation unrelated to the matmul being
        // processed; Listing 3's frontier must not pull them in.
        let (gs, gd, ri) = running_example();
        let mut stats = SatStats { saturated: true, ..Default::default() };
        let rules = lemmas::standard_rewrites();
        let ctx = RewriteCtx::default();
        let cfg = InferConfig::default();
        let mut scratch = EGraph::new();
        // node 0 in gs is the matmul
        let (cands, timing) =
            compute_node_out_rel(0, &gs, &gd, &ri, &rules, &ctx, &cfg, &mut scratch, &mut stats)
                .unwrap();
        assert!(!cands.is_empty());
        // explored G_d nodes: C_1, C_2, D_1, D_2 — but not F_1/F_2 (need E)
        assert!(
            timing.explored_gd <= 4,
            "frontier exploration leaked to unrelated nodes: {}",
            timing.explored_gd
        );
    }

    #[test]
    fn per_node_timings_recorded() {
        let (gs, gd, ri) = running_example();
        let out = check_refinement(&gs, &gd, &ri, &InferConfig::default()).unwrap();
        assert_eq!(out.per_node.len(), gs.num_nodes());
        assert!(out.stats.total_applications() > 0, "lemmas were applied");
    }
}
