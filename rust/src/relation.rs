//! Relations (paper §3.2): sets of tensor-expression pairs mapping tensors
//! of `G_s` to clean expressions over tensors of `G_d`.
//!
//! A relation may hold several mappings per tensor (replication, and the
//! sum-vs-concat alternatives of the running example). Insertion applies
//! the paper's self-provable pruning (§4.3.2): at most one expression per
//! distinct leaf signature — the smallest — and a bounded number of
//! signatures per tensor.
//!
//! **Conditional relations (MoE routing).** A mapping may contain the
//! router-keyed `dispatch`/`combine` ops. Such an expression is clean only
//! *conditioned on* its router operands (its [`Expr::guard_leaves`]): it
//! reconstructs the `G_s` tensor because the referenced `G_d` router tensor
//! is the very routing decision the sequential graph computed (the e-graph
//! only ever equates router tensors that are provably the same, so crossed
//! router tags never satisfy the guard). [`Relation::guards_for`] exposes
//! the guard tensors per mapping; [`Relation::conditional_tensors`] lists
//! the tensors whose mappings are router-conditioned.

use crate::egraph::CleanCand;
use crate::expr::print::Namer;
use crate::expr::{parse, Expr, Side, TensorRef};
use crate::ir::{Graph, TensorId};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use rustc_hash::FxHashMap;

/// Max mappings kept per tensor (distinct leaf signatures).
pub const K_PER_TENSOR: usize = 4;

#[derive(Debug, Clone, Default)]
pub struct Relation {
    map: FxHashMap<TensorId, Vec<CleanCand>>,
}

impl Relation {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, t: TensorId) -> &[CleanCand] {
        self.map.get(&t).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn contains(&self, t: TensorId) -> bool {
        self.map.get(&t).is_some_and(|v| !v.is_empty())
    }

    pub fn tensors(&self) -> impl Iterator<Item = TensorId> + '_ {
        self.map.keys().copied()
    }

    /// Insert with self-provable pruning: keep min-cost per leaf signature,
    /// at most [`K_PER_TENSOR`] signatures (cheapest first).
    pub fn insert(&mut self, t: TensorId, cand: CleanCand) {
        debug_assert!(cand.expr.is_clean(), "relation entries must be clean");
        let entry = self.map.entry(t).or_default();
        if let Some(existing) = entry.iter_mut().find(|c| c.leaves == cand.leaves) {
            if cand.cost < existing.cost {
                *existing = cand;
            }
            return;
        }
        entry.push(cand);
        entry.sort_by_key(|c| c.cost);
        entry.truncate(K_PER_TENSOR);
    }

    pub fn insert_all(&mut self, t: TensorId, cands: impl IntoIterator<Item = CleanCand>) {
        for c in cands {
            self.insert(t, c);
        }
    }

    /// Completeness (§3.2): does the relation map every tensor in `required`?
    pub fn is_complete_for(&self, required: &[TensorId]) -> bool {
        required.iter().all(|&t| self.contains(t))
    }

    /// Tensors whose mappings include a router-conditioned (guarded)
    /// expression — the MoE-style conditional relations.
    pub fn conditional_tensors(&self) -> Vec<TensorId> {
        let mut out: Vec<TensorId> = self
            .map
            .iter()
            .filter(|(_, cands)| cands.iter().any(|c| c.expr.is_router_conditioned()))
            .map(|(&t, _)| t)
            .collect();
        out.sort_unstable();
        out
    }

    /// Union of the guard (router) leaves across all mappings of `t` —
    /// the `G_d` tensors the conditional mappings are predicated on.
    pub fn guards_for(&self, t: TensorId) -> Vec<TensorRef> {
        let mut out: Vec<TensorRef> =
            self.get(t).iter().flat_map(|c| c.expr.guard_leaves()).collect();
        out.sort();
        out.dedup();
        out
    }

    /// Restrict to `tensors`, keeping only expressions whose leaves satisfy
    /// `leaf_ok` (Listing 1 line 9: final `R_o` must use only `O(G_d)`).
    pub fn restrict(
        &self,
        tensors: &[TensorId],
        leaf_ok: impl Fn(TensorRef) -> bool,
    ) -> Relation {
        let mut out = Relation::new();
        for &t in tensors {
            for cand in self.get(t) {
                if cand.leaves.iter().all(|&l| leaf_ok(l)) {
                    out.insert(t, cand.clone());
                }
            }
        }
        out
    }

    // ---- textual / JSON interchange ----

    /// Parse a relation from JSON: `{"A": ["concat(A_1, A_2; dim=1)"]}`.
    /// Keys are `G_s` tensor names, values are expression strings whose
    /// leaves are `G_d` tensor names.
    pub fn from_json(j: &Json, gs: &Graph, gd: &Graph) -> Result<Relation> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("relation must be a JSON object"))?;
        let mut rel = Relation::new();
        for (name, exprs) in obj {
            let t = gs
                .tensor_by_name(name)
                .ok_or_else(|| anyhow!("relation names unknown G_s tensor '{name}'"))?;
            let arr = exprs.as_arr().ok_or_else(|| anyhow!("'{name}' must map to a list"))?;
            for e in arr {
                let text = e.as_str().ok_or_else(|| anyhow!("expression must be a string"))?;
                let resolve = |n: &str| gd.tensor_by_name(n).map(TensorRef::d);
                let expr = parse::parse(text, &resolve)
                    .with_context(|| format!("parsing relation for '{name}'"))?;
                if !expr.is_clean() {
                    bail!("relation expression for '{name}' is not clean: {text}");
                }
                let leaves = expr.leaves();
                let cost = expr.size() as u32;
                rel.insert(t, CleanCand { expr, cost, leaves });
            }
        }
        Ok(rel)
    }

    pub fn to_json(&self, gs: &Graph, gd: &Graph) -> Json {
        let namer = Namer { gs, gd };
        let mut obj = std::collections::BTreeMap::new();
        for (&t, cands) in &self.map {
            let exprs: Vec<Json> = cands
                .iter()
                .map(|c| Json::str(crate::expr::print::render(&c.expr, &namer)))
                .collect();
            obj.insert(gs.tensor(t).name.clone(), Json::Arr(exprs));
        }
        Json::Obj(obj)
    }

    /// Shape-check every mapping: the expression's result shape must equal
    /// the `G_s` tensor's shape.
    pub fn validate_shapes(&self, gs: &Graph, gd: &Graph) -> Result<()> {
        for (&t, cands) in &self.map {
            for c in cands {
                let shape = expr_shape(&c.expr, gd)
                    .with_context(|| format!("mapping for '{}'", gs.tensor(t).name))?;
                if shape != gs.shape(t) {
                    bail!(
                        "mapping for '{}' has shape {:?}, expected {:?}",
                        gs.tensor(t).name,
                        shape,
                        gs.shape(t)
                    );
                }
            }
        }
        Ok(())
    }
}

/// Infer the shape an expression over `G_d` tensors evaluates to.
pub fn expr_shape(e: &Expr, gd: &Graph) -> Result<Vec<i64>> {
    match e {
        Expr::Leaf(t) => {
            if t.side != Side::D {
                bail!("relation leaf on the wrong side: {:?}", t);
            }
            Ok(gd.shape(t.id).to_vec())
        }
        Expr::Op(op, args) => {
            let shapes: Vec<Vec<i64>> =
                args.iter().map(|a| expr_shape(a, gd)).collect::<Result<_>>()?;
            let refs: Vec<&[i64]> = shapes.iter().map(|s| s.as_slice()).collect();
            op.infer_shape(&refs, None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    fn cand(expr: Expr) -> CleanCand {
        let leaves = expr.leaves();
        let cost = expr.size() as u32;
        CleanCand { expr, cost, leaves }
    }

    fn graphs() -> (Graph, Graph) {
        let mut gs = Graph::new("gs");
        gs.input("A", vec![4, 4]);
        gs.input("B", vec![4, 4]);
        let mut gd = Graph::new("gd");
        gd.input("A_1", vec![4, 2]);
        gd.input("A_2", vec![4, 2]);
        gd.input("B_r", vec![4, 4]);
        (gs, gd)
    }

    #[test]
    fn self_provable_pruning_on_insert() {
        let mut r = Relation::new();
        let big = cand(Expr::op(
            Op::Concat { dim: 1 },
            vec![
                Expr::op(
                    Op::Slice { dim: 1, start: 0.into(), end: 1.into() },
                    vec![Expr::leaf(TensorRef::d(0))],
                ),
                Expr::op(
                    Op::Slice { dim: 1, start: 1.into(), end: 2.into() },
                    vec![Expr::leaf(TensorRef::d(0))],
                ),
            ],
        ));
        let small = cand(Expr::leaf(TensorRef::d(0)));
        r.insert(0, big);
        r.insert(0, small);
        // same leaf signature {d0} -> only the smallest survives
        assert_eq!(r.get(0).len(), 1);
        assert_eq!(r.get(0)[0].cost, 0);
    }

    #[test]
    fn distinct_signatures_coexist() {
        let mut r = Relation::new();
        r.insert(0, cand(Expr::leaf(TensorRef::d(0))));
        r.insert(
            0,
            cand(Expr::op(
                Op::SumN,
                vec![Expr::leaf(TensorRef::d(1)), Expr::leaf(TensorRef::d(2))],
            )),
        );
        assert_eq!(r.get(0).len(), 2);
    }

    #[test]
    fn json_roundtrip_and_clean_enforcement() {
        let (gs, gd) = graphs();
        let j = Json::parse(r#"{"A": ["concat(A_1, A_2; dim=1)"], "B": ["B_r"]}"#).unwrap();
        let r = Relation::from_json(&j, &gs, &gd).unwrap();
        assert!(r.contains(gs.tensor_by_name("A").unwrap()));
        r.validate_shapes(&gs, &gd).unwrap();
        let back = r.to_json(&gs, &gd);
        let r2 = Relation::from_json(&back, &gs, &gd).unwrap();
        assert_eq!(r2.len(), r.len());

        // unclean expressions rejected
        let bad = Json::parse(r#"{"A": ["matmul(A_1, A_2)"]}"#).unwrap();
        assert!(Relation::from_json(&bad, &gs, &gd).is_err());
    }

    #[test]
    fn shape_validation_catches_mismatch() {
        let (gs, gd) = graphs();
        let j = Json::parse(r#"{"A": ["A_1"]}"#).unwrap(); // [4,2] != [4,4]
        let r = Relation::from_json(&j, &gs, &gd).unwrap();
        assert!(r.validate_shapes(&gs, &gd).is_err());
    }

    #[test]
    fn conditional_relations_parse_and_report_guards() {
        let mut gs = Graph::new("gs");
        gs.input("Y", vec![4, 4]);
        let mut gd = Graph::new("gd");
        gd.input("mask_d", vec![4, 2]);
        gd.input("y0_d", vec![4, 4]);
        gd.input("y1_d", vec![4, 4]);
        let j = Json::parse(
            r#"{"Y": ["combine(mask_d, y0_d, y1_d; experts=2)"]}"#,
        )
        .unwrap();
        let r = Relation::from_json(&j, &gs, &gd).unwrap();
        r.validate_shapes(&gs, &gd).unwrap();
        let y = gs.tensor_by_name("Y").unwrap();
        assert_eq!(r.conditional_tensors(), vec![y]);
        let mask = gd.tensor_by_name("mask_d").unwrap();
        assert_eq!(r.guards_for(y), vec![TensorRef::d(mask)], "router is the guard");
        // an unconditional mapping reports no guards
        let j2 = Json::parse(r#"{"Y": ["y0_d"]}"#).unwrap();
        let r2 = Relation::from_json(&j2, &gs, &gd).unwrap();
        assert!(r2.conditional_tensors().is_empty());
        assert!(r2.guards_for(y).is_empty());
        // topk stays unclean and is rejected in a relation expression
        let bad = Json::parse(r#"{"Y": ["topk(y0_d; k=1)"]}"#).unwrap();
        assert!(Relation::from_json(&bad, &gs, &gd).is_err());
    }

    #[test]
    fn completeness_and_restrict() {
        let (gs, _gd) = graphs();
        let a = gs.tensor_by_name("A").unwrap();
        let b = gs.tensor_by_name("B").unwrap();
        let mut r = Relation::new();
        r.insert(a, cand(Expr::leaf(TensorRef::d(2))));
        assert!(r.is_complete_for(&[a]));
        assert!(!r.is_complete_for(&[a, b]));
        let restricted = r.restrict(&[a], |t| t.id != 2);
        assert!(!restricted.contains(a), "leaf filter applies");
    }
}
