//! Test-only fault injection ("chaos hooks").
//!
//! The saturation engine calls [`on_lemma_application`] immediately before
//! every lemma applier. With the `chaos` Cargo feature enabled, tests can
//! arm a fault against a named lemma — panic or a wall-clock stall on its
//! Nth application — to prove end-to-end that the coordinator and the fuzz
//! oracle convert worker faults into `Inconclusive` verdicts instead of
//! aborting, hanging, or misreporting them as refutations.
//!
//! Without the feature (every production build) the hook is an empty
//! `#[inline(always)]` function: zero cost, zero behavior change.
//!
//! Faults fire exactly once. A fired fault stays in the armed list (marked
//! spent) so tests can assert it actually triggered; [`disarm_all`] resets
//! the global state between tests. The armed list is process-global —
//! chaos tests must serialize on a shared mutex (see `rust/tests/chaos.rs`)
//! and should pin `threads = 1` for deterministic victim selection.

#[cfg(feature = "chaos")]
mod imp {
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum FaultAction {
        /// Panic inside the applier (a poisoned-lemma crash).
        Panic,
        /// Stall for the given duration (a wedged applier / runaway solver).
        Spin(Duration),
    }

    #[derive(Debug)]
    struct Armed {
        rule: String,
        /// Fire on the Nth application of `rule` (1-based).
        nth: u64,
        action: FaultAction,
        seen: u64,
        fired: bool,
    }

    static FAULTS: Mutex<Vec<Armed>> = Mutex::new(Vec::new());

    /// Lock that tolerates poisoning: the whole point of this module is to
    /// panic while the lock's owner list is consistent, so recover the data.
    fn faults() -> std::sync::MutexGuard<'static, Vec<Armed>> {
        match FAULTS.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Arm `action` against the `nth` (1-based) application of `rule`.
    pub fn arm(rule: &str, nth: u64, action: FaultAction) {
        faults().push(Armed { rule: rule.to_string(), nth, action, seen: 0, fired: false });
    }

    /// Clear all armed faults and counters.
    pub fn disarm_all() {
        faults().clear();
    }

    /// Did an armed fault against `rule` actually fire?
    pub fn fired(rule: &str) -> bool {
        faults().iter().any(|f| f.rule == rule && f.fired)
    }

    /// Is any fault armed (fired or not)? The fingerprint cache checks this
    /// and bypasses itself entirely while faults are in play: replayed
    /// regions would skip lemma applications (shifting which application is
    /// "Nth"), and a region computed mid-fault must never be stored.
    pub fn any_armed() -> bool {
        !faults().is_empty()
    }

    pub fn on_lemma_application(rule: &str) {
        // Decide under the lock, act after dropping it: panicking while
        // holding the guard would be survivable (see `faults`) but a spin
        // would serialize every other worker on this mutex.
        let action = {
            let mut g = faults();
            let mut hit = None;
            for f in g.iter_mut() {
                if f.fired || f.rule != rule {
                    continue;
                }
                f.seen += 1;
                if f.seen == f.nth {
                    f.fired = true;
                    hit = Some(f.action);
                    break;
                }
            }
            hit
        };
        match action {
            None => {}
            Some(FaultAction::Panic) => {
                panic!("chaos: injected panic in lemma applier '{rule}'")
            }
            Some(FaultAction::Spin(d)) => {
                // Sleep-loop rather than busy-wait: the stall is what is
                // being simulated, not CPU burn, and short sleeps keep the
                // wall clock honest under test-runner load.
                let end = Instant::now() + d;
                while Instant::now() < end {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }
}

#[cfg(feature = "chaos")]
pub use imp::{any_armed, arm, disarm_all, fired, on_lemma_application, FaultAction};

#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub fn on_lemma_application(_rule: &str) {}

#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub fn any_armed() -> bool {
    false
}
