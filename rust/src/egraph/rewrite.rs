//! Conditional rewrite rules and bounded equality saturation.
//!
//! A [`Rewrite`] pairs an LHS pattern with an applier closure. The applier
//! receives the substitution and may consult the symbolic solver (lemma
//! conditions, §5.2) and the e-graph itself (constrained lemmas only fire
//! when their target subterms already exist, §4.3.2). It returns the class
//! ids to union with the matched root.
//!
//! Saturation tracks per-rule application counts — these counters are the
//! raw data behind the paper's Figure 7 lemma-usage heatmap.

use super::ematch::{Pat, Subst};
use super::enode::{EGraph, Id};
use crate::symbolic::{LinExpr, Solver, Truth};
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::Mutex;
use std::time::Instant;

/// Kind of a cached solver query (both reduce to a question about `a - b`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum CondKind {
    Eq,
    Ge,
}

/// Context available to appliers.
///
/// Besides the symbolic solver it carries a condition-result cache: lemma
/// side-conditions are keyed by the normalized difference `a - b`, and the
/// same symbolic comparisons recur for every operator of a model (slice
/// bounds, partition offsets), so each distinct condition is proved once per
/// verification run instead of once per operator. The cache assumes
/// the solver's constraint store is fixed after construction — which holds
/// for the inference walk, where constraints come from capture, not lemmas.
pub struct RewriteCtx {
    pub solver: Solver,
    cond_cache: Mutex<FxHashMap<(CondKind, LinExpr), Truth>>,
    /// Pipeline channels whose buffer slot fails the schedule's liveness
    /// audit (`crate::schedule::quarantined_channels`). The
    /// `recv_of_send_identity` lemma refuses to collapse a quarantined
    /// channel even when its send/recv tags match — a lowering that stamps
    /// both sides of a hazardous boundary with the occupant epoch must not
    /// verify. Empty by default (no behavior change outside scheduled PP).
    quarantined_channels: FxHashSet<usize>,
}

impl Default for RewriteCtx {
    fn default() -> Self {
        RewriteCtx::with_solver(Solver::new())
    }
}

impl RewriteCtx {
    pub fn with_solver(solver: Solver) -> Self {
        RewriteCtx {
            solver,
            cond_cache: Mutex::new(FxHashMap::default()),
            quarantined_channels: FxHashSet::default(),
        }
    }

    /// Mark channels as slot-liveness violators (see field docs).
    pub fn quarantine_channels(&mut self, channels: impl IntoIterator<Item = usize>) {
        self.quarantined_channels.extend(channels);
    }

    /// Is this channel's buffer slot under a liveness quarantine?
    pub fn channel_quarantined(&self, chan: usize) -> bool {
        self.quarantined_channels.contains(&chan)
    }

    /// Memoized `solver.check_eq`.
    pub fn check_eq(&self, a: &LinExpr, b: &LinExpr) -> Truth {
        self.cached(CondKind::Eq, a, b, |s, a, b| s.check_eq(a, b))
    }

    /// Memoized `solver.check_ge`.
    pub fn check_ge(&self, a: &LinExpr, b: &LinExpr) -> Truth {
        self.cached(CondKind::Ge, a, b, |s, a, b| s.check_ge(a, b))
    }

    fn cached(
        &self,
        kind: CondKind,
        a: &LinExpr,
        b: &LinExpr,
        f: impl Fn(&Solver, &LinExpr, &LinExpr) -> Truth,
    ) -> Truth {
        let key = (kind, a.sub(b));
        if let Some(&t) = self.cond_cache.lock().unwrap().get(&key) {
            return t;
        }
        let t = f(&self.solver, a, b);
        self.cond_cache.lock().unwrap().insert(key, t);
        t
    }
}

type Applier = dyn Fn(&mut EGraph, &Subst, &RewriteCtx) -> Vec<Id> + Send + Sync;

pub struct Rewrite {
    pub name: &'static str,
    pub lhs: Pat,
    pub apply: Box<Applier>,
}

impl Rewrite {
    pub fn new(
        name: &'static str,
        lhs: Pat,
        apply: impl Fn(&mut EGraph, &Subst, &RewriteCtx) -> Vec<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite { name, lhs, apply: Box::new(apply) }
    }
}

/// Why saturation was cut short by a *hard* resource budget.
///
/// Running out of `max_iters` is deliberately NOT an exhaustion: iteration
/// caps bound rewrite depth by design and the non-saturated fixpoint is
/// still a sound under-approximation to search in. Exhaustion marks the
/// two events where the engine had to abandon work it would otherwise have
/// done — and where a downstream "no clean mapping" must therefore be
/// reported as `Inconclusive`, never as a refinement failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Exhaustion {
    /// `EGraph::n_nodes` crossed `max_nodes`; the pass aborted mid-apply.
    NodeBudget,
    /// The cooperative wall-clock `deadline` passed.
    Deadline,
}

#[derive(Debug, Clone, Copy)]
pub struct SaturationLimits {
    pub max_iters: usize,
    pub max_nodes: usize,
    /// Cooperative wall-clock deadline. Checked at every iteration start
    /// and periodically inside the apply phase; `None` = no deadline.
    pub deadline: Option<Instant>,
}

impl Default for SaturationLimits {
    fn default() -> Self {
        SaturationLimits { max_iters: 10, max_nodes: 50_000, deadline: None }
    }
}

impl SaturationLimits {
    pub fn new(max_iters: usize, max_nodes: usize) -> Self {
        SaturationLimits { max_iters, max_nodes, deadline: None }
    }

    pub fn with_deadline(mut self, deadline: Option<Instant>) -> Self {
        self.deadline = deadline;
        self
    }

    fn deadline_passed(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

#[derive(Debug, Default, Clone)]
pub struct SatStats {
    /// Per-rule successful applications (new equalities discovered).
    pub applied: FxHashMap<&'static str, u64>,
    pub iterations: usize,
    pub saturated: bool,
    /// Set when a hard budget (node cap / deadline) aborted the pass.
    pub exhausted: Option<Exhaustion>,
}

impl SatStats {
    pub fn merge(&mut self, other: &SatStats) {
        for (k, v) in &other.applied {
            *self.applied.entry(k).or_insert(0) += v;
        }
        self.iterations += other.iterations;
        self.saturated &= other.saturated;
        if self.exhausted.is_none() {
            self.exhausted = other.exhausted;
        }
    }

    pub fn total_applications(&self) -> u64 {
        self.applied.values().sum()
    }
}

/// Root op-tag of a pattern (None for Var roots / op-class matchers).
fn root_tag(pat: &super::ematch::Pat) -> Option<crate::ir::OpTag> {
    use super::ematch::{POp, Pat};
    match pat {
        Pat::Node { op, .. } => match op {
            POp::Exact(o) => Some(o.tag()),
            POp::Bind { tag, .. } => Some(*tag),
            _ => None,
        },
        Pat::Var(_) => None,
    }
}

/// How `saturate_with` selects the classes to re-match each iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatchStrategy {
    /// Iteration 0 matches every class; later iterations re-match only the
    /// dirty-class worklist — classes unioned, congruence-merged, created,
    /// or given a new parent since the previous pass, plus their transitive
    /// parents ([`EGraph::take_dirty_closure`]). A pattern can only newly
    /// match where something in its (applier-visible) scope changed, so
    /// this reaches the same fixpoint as a full rescan; the differential
    /// tests hold it to that.
    Incremental,
    /// Re-match every class every iteration — the pre-incremental engine,
    /// kept as the oracle for differential testing.
    FullRescan,
}

/// Run equality saturation until fixpoint or limits (incremental matching).
pub fn saturate(
    eg: &mut EGraph,
    rules: &[Rewrite],
    ctx: &RewriteCtx,
    limits: SaturationLimits,
) -> SatStats {
    saturate_with(eg, rules, ctx, limits, MatchStrategy::Incremental)
}

/// Full-rescan oracle (see [`MatchStrategy::FullRescan`]).
pub fn saturate_full_rescan(
    eg: &mut EGraph,
    rules: &[Rewrite],
    ctx: &RewriteCtx,
    limits: SaturationLimits,
) -> SatStats {
    saturate_with(eg, rules, ctx, limits, MatchStrategy::FullRescan)
}

/// Run equality saturation until fixpoint or limits.
pub fn saturate_with(
    eg: &mut EGraph,
    rules: &[Rewrite],
    ctx: &RewriteCtx,
    limits: SaturationLimits,
    strategy: MatchStrategy,
) -> SatStats {
    let mut stats = SatStats { saturated: true, ..Default::default() };
    let rule_tags: Vec<Option<crate::ir::OpTag>> =
        rules.iter().map(|r| root_tag(&r.lhs)).collect();
    // Reused buffers: one jobs vector, one candidate list, and one
    // per-(rule, class) match buffer for the whole call, instead of fresh
    // allocations per iteration (see EXPERIMENTS.md §Perf).
    let mut jobs: Vec<(usize, Id, Subst)> = Vec::new();
    let mut candidates: Vec<Id> = Vec::new();
    let mut matches: Vec<Subst> = Vec::new();
    for iter in 0..limits.max_iters {
        if limits.deadline_passed() {
            stats.saturated = false;
            stats.exhausted = Some(Exhaustion::Deadline);
            return stats;
        }
        stats.iterations = iter + 1;
        // Worklist of classes to re-match; `None` = match everything.
        // Draining even when ignored keeps the touched set bounded.
        let worklist = {
            let touched = eg.take_dirty_closure();
            if iter == 0 || strategy == MatchStrategy::FullRescan {
                None
            } else {
                Some(touched)
            }
        };
        // Phase 1: match against a snapshot of the graph. Rules with a
        // specific root tag scan the e-graph's persistent tag index — the
        // single biggest cost lever on the per-operator hot path (see
        // EXPERIMENTS.md §Perf) — intersected with the worklist when one
        // is active, iterating whichever side is smaller. Candidate lists
        // are sorted so job order is canonical (by class id, rule-major):
        // identical for both strategies and across runs, which is what the
        // differential tests rely on.
        let mut all_classes: Vec<Id> = match &worklist {
            None => eg.class_ids(),
            Some(w) => w.iter().copied().collect(),
        };
        all_classes.sort_unstable();
        jobs.clear();
        for (ri, rule) in rules.iter().enumerate() {
            match rule_tags[ri] {
                Some(tag) => {
                    let Some(tagged) = eg.tag_classes(tag) else { continue };
                    candidates.clear();
                    match &worklist {
                        None => candidates.extend(tagged.iter().copied()),
                        Some(w) if w.len() <= tagged.len() => {
                            candidates.extend(w.iter().copied().filter(|id| tagged.contains(id)))
                        }
                        Some(w) => {
                            candidates.extend(tagged.iter().copied().filter(|id| w.contains(id)))
                        }
                    }
                    candidates.sort_unstable();
                    for &root in &candidates {
                        super::ematch::ematch_into(eg, &rule.lhs, root, &mut matches);
                        for subst in matches.drain(..) {
                            jobs.push((ri, root, subst));
                        }
                    }
                }
                None => {
                    for &root in &all_classes {
                        super::ematch::ematch_into(eg, &rule.lhs, root, &mut matches);
                        for subst in matches.drain(..) {
                            jobs.push((ri, root, subst));
                        }
                    }
                }
            }
        }
        // Phase 2: apply.
        let mut changed = false;
        for (ji, (ri, root, subst)) in jobs.drain(..).enumerate() {
            if eg.n_nodes > limits.max_nodes {
                stats.saturated = false;
                stats.exhausted = Some(Exhaustion::NodeBudget);
                return stats;
            }
            // Deadline re-check every few jobs: appliers are cheap
            // individually but a single iteration can queue thousands.
            if ji % 8 == 0 && limits.deadline_passed() {
                stats.saturated = false;
                stats.exhausted = Some(Exhaustion::Deadline);
                return stats;
            }
            let rule = &rules[ri];
            crate::chaos::on_lemma_application(rule.name);
            let equivs = (rule.apply)(eg, &subst, ctx);
            for id in equivs {
                match eg.union(root, id) {
                    Ok(true) => {
                        *stats.applied.entry(rule.name).or_insert(0) += 1;
                        changed = true;
                    }
                    Ok(false) => {}
                    Err(_) => {
                        // shape-mismatched union — a buggy lemma; skip but
                        // count nothing. Lemma validation catches these.
                    }
                }
            }
        }
        eg.rebuild();
        // A slow applier (or an injected chaos spin) can blow the deadline
        // between the periodic phase-2 checks; re-check at iteration end so
        // an overrun is always reported as a Deadline exhaustion and never
        // as a clean fixpoint.
        if limits.deadline_passed() {
            stats.saturated = false;
            stats.exhausted = Some(Exhaustion::Deadline);
            return stats;
        }
        // Identical stopping rule in both strategies (no counted unions),
        // so incremental and full-rescan runs stay comparable job-for-job.
        if !changed {
            return stats;
        }
    }
    stats.saturated = false;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::TensorRef;
    use crate::ir::{Op, OpTag};

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    /// add(x, y) -> sum(x, y): normalization rewrite used by the real
    /// lemma library.
    fn add_to_sum() -> Rewrite {
        Rewrite::new(
            "add_to_sum",
            Pat::exact(Op::Add, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                let (Some(x), Some(y)) = (s.var(0), s.var(1)) else { return vec![] };
                eg.add_op(Op::SumN, vec![x, y]).into_iter().collect()
            },
        )
    }

    /// neg(neg(x)) -> x
    fn neg_involution() -> Rewrite {
        Rewrite::new(
            "neg_involution",
            Pat::exact(Op::Neg, vec![Pat::exact(Op::Neg, vec![Pat::var(0)])]),
            |_eg, s, _| s.var(0).into_iter().collect(),
        )
    }

    #[test]
    fn saturation_finds_equivalence() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let add = eg.add_op(Op::Add, vec![a, b]).unwrap();
        let sum = eg.add_op(Op::SumN, vec![a, b]).unwrap();
        assert!(!eg.same(add, sum));
        let stats = saturate(&mut eg, &[add_to_sum()], &RewriteCtx::default(), Default::default());
        assert!(eg.same(add, sum));
        assert_eq!(stats.applied["add_to_sum"], 1);
        assert!(stats.saturated);
    }

    #[test]
    fn involution_collapses() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let n1 = eg.add_op(Op::Neg, vec![a]).unwrap();
        let n2 = eg.add_op(Op::Neg, vec![n1]).unwrap();
        saturate(&mut eg, &[neg_involution()], &RewriteCtx::default(), Default::default());
        assert!(eg.same(n2, a));
    }

    #[test]
    fn iteration_limit_respected() {
        // A rule that genuinely never saturates: every application unions a
        // brand-new leaf into the matched class (the unconstrained-rewrite
        // blowup §4.3.2 warns about).
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNTER: AtomicU32 = AtomicU32::new(1000);
        let grow = Rewrite::new(
            "grow",
            Pat::bind(OpTag::Neg, 0, vec![Pat::var(0)]),
            |eg, _s, _| {
                let fresh = COUNTER.fetch_add(1, Ordering::Relaxed);
                vec![eg.add_leaf(t(fresh), vec![4])]
            },
        );
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        eg.add_op(Op::Neg, vec![a]).unwrap();
        let stats = saturate(
            &mut eg,
            &[grow],
            &RewriteCtx::default(),
            SaturationLimits::new(3, 100_000),
        );
        assert!(!stats.saturated);
        assert_eq!(stats.iterations, 3);
        assert_eq!(stats.exhausted, None, "iteration cap is not a hard exhaustion");
    }

    #[test]
    fn node_budget_marks_exhaustion() {
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNTER: AtomicU32 = AtomicU32::new(5000);
        let grow = Rewrite::new(
            "grow2",
            Pat::bind(OpTag::Neg, 0, vec![Pat::var(0)]),
            |eg, _s, _| {
                let fresh = COUNTER.fetch_add(1, Ordering::Relaxed);
                vec![eg.add_leaf(t(fresh), vec![4])]
            },
        );
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        eg.add_op(Op::Neg, vec![a]).unwrap();
        let stats = saturate(
            &mut eg,
            &[grow],
            &RewriteCtx::default(),
            SaturationLimits::new(50, 4),
        );
        assert!(!stats.saturated);
        assert_eq!(stats.exhausted, Some(Exhaustion::NodeBudget));
    }

    #[test]
    fn elapsed_deadline_marks_exhaustion_before_any_work() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        eg.add_op(Op::Add, vec![a, b]).unwrap();
        let limits =
            SaturationLimits::default().with_deadline(Some(std::time::Instant::now()));
        let stats = saturate(&mut eg, &[add_to_sum()], &RewriteCtx::default(), limits);
        assert!(!stats.saturated);
        assert_eq!(stats.exhausted, Some(Exhaustion::Deadline));
        assert_eq!(stats.total_applications(), 0);
    }

    #[test]
    fn incremental_matches_full_rescan_on_toy_graph() {
        let build = || {
            let mut eg = EGraph::new();
            let a = eg.add_leaf(t(0), vec![4]);
            let b = eg.add_leaf(t(1), vec![4]);
            let c = eg.add_leaf(t(2), vec![4]);
            let ab = eg.add_op(Op::Add, vec![a, b]).unwrap();
            let abc = eg.add_op(Op::Add, vec![ab, c]).unwrap();
            let n = eg.add_op(Op::Neg, vec![abc]).unwrap();
            let nn = eg.add_op(Op::Neg, vec![n]).unwrap();
            (eg, vec![a, b, c, ab, abc, n, nn])
        };
        let ctx = RewriteCtx::default();
        let (mut inc, ids) = build();
        let (mut full, ids2) = build();
        assert_eq!(ids, ids2, "deterministic construction");
        let si = saturate(&mut inc, &[add_to_sum(), neg_involution()], &ctx, Default::default());
        let sf = saturate_full_rescan(
            &mut full,
            &[add_to_sum(), neg_involution()],
            &ctx,
            Default::default(),
        );
        assert_eq!(si.applied, sf.applied, "per-rule counts agree");
        for (i, &x) in ids.iter().enumerate() {
            for &y in &ids[i + 1..] {
                assert_eq!(inc.same(x, y), full.same(x, y), "partition agrees on ({x},{y})");
            }
        }
    }

    #[test]
    fn per_rule_counters() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let c = eg.add_leaf(t(2), vec![4]);
        eg.add_op(Op::Add, vec![a, b]).unwrap();
        eg.add_op(Op::Add, vec![b, c]).unwrap();
        let stats = saturate(&mut eg, &[add_to_sum()], &RewriteCtx::default(), Default::default());
        assert_eq!(stats.applied["add_to_sum"], 2);
        assert_eq!(stats.total_applications(), 2);
    }
}
