//! Conditional rewrite rules and bounded equality saturation.
//!
//! A [`Rewrite`] pairs an LHS pattern with an applier closure. The applier
//! receives the substitution and may consult the symbolic solver (lemma
//! conditions, §5.2) and the e-graph itself (constrained lemmas only fire
//! when their target subterms already exist, §4.3.2). It returns the class
//! ids to union with the matched root.
//!
//! Saturation tracks per-rule application counts — these counters are the
//! raw data behind the paper's Figure 7 lemma-usage heatmap.

use super::ematch::{Pat, Subst};
use super::enode::{EGraph, Id};
use crate::symbolic::Solver;
use rustc_hash::FxHashMap;

/// Context available to appliers.
pub struct RewriteCtx {
    pub solver: Solver,
}

impl Default for RewriteCtx {
    fn default() -> Self {
        RewriteCtx { solver: Solver::new() }
    }
}

type Applier = dyn Fn(&mut EGraph, &Subst, &RewriteCtx) -> Vec<Id> + Send + Sync;

pub struct Rewrite {
    pub name: &'static str,
    pub lhs: Pat,
    pub apply: Box<Applier>,
}

impl Rewrite {
    pub fn new(
        name: &'static str,
        lhs: Pat,
        apply: impl Fn(&mut EGraph, &Subst, &RewriteCtx) -> Vec<Id> + Send + Sync + 'static,
    ) -> Self {
        Rewrite { name, lhs, apply: Box::new(apply) }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SaturationLimits {
    pub max_iters: usize,
    pub max_nodes: usize,
}

impl Default for SaturationLimits {
    fn default() -> Self {
        SaturationLimits { max_iters: 10, max_nodes: 50_000 }
    }
}

#[derive(Debug, Default, Clone)]
pub struct SatStats {
    /// Per-rule successful applications (new equalities discovered).
    pub applied: FxHashMap<&'static str, u64>,
    pub iterations: usize,
    pub saturated: bool,
}

impl SatStats {
    pub fn merge(&mut self, other: &SatStats) {
        for (k, v) in &other.applied {
            *self.applied.entry(k).or_insert(0) += v;
        }
        self.iterations += other.iterations;
        self.saturated &= other.saturated;
    }

    pub fn total_applications(&self) -> u64 {
        self.applied.values().sum()
    }
}

/// Root op-tag of a pattern (None for Var roots / op-class matchers).
fn root_tag(pat: &super::ematch::Pat) -> Option<crate::ir::OpTag> {
    use super::ematch::{POp, Pat};
    match pat {
        Pat::Node { op, .. } => match op {
            POp::Exact(o) => Some(o.tag()),
            POp::Bind { tag, .. } => Some(*tag),
            _ => None,
        },
        Pat::Var(_) => None,
    }
}

/// Run equality saturation until fixpoint or limits.
pub fn saturate(
    eg: &mut EGraph,
    rules: &[Rewrite],
    ctx: &RewriteCtx,
    limits: SaturationLimits,
) -> SatStats {
    use rustc_hash::FxHashSet;
    let mut stats = SatStats { saturated: true, ..Default::default() };
    let rule_tags: Vec<Option<crate::ir::OpTag>> =
        rules.iter().map(|r| root_tag(&r.lhs)).collect();
    for iter in 0..limits.max_iters {
        stats.iterations = iter + 1;
        // Tag index: classes that contain at least one node of each op tag.
        // Rules whose root matches a specific tag only scan those classes —
        // the single biggest cost lever on the per-operator hot path (see
        // EXPERIMENTS.md §Perf).
        let all_classes = eg.class_ids();
        let mut by_tag: FxHashMap<crate::ir::OpTag, Vec<Id>> = FxHashMap::default();
        for &id in &all_classes {
            let mut seen: FxHashSet<crate::ir::OpTag> = FxHashSet::default();
            for node in &eg.class(id).nodes {
                if let super::enode::ELang::Op(op) = &node.lang {
                    if seen.insert(op.tag()) {
                        by_tag.entry(op.tag()).or_default().push(id);
                    }
                }
            }
        }
        // Phase 1: match against a snapshot of the graph.
        static EMPTY: Vec<Id> = Vec::new();
        let mut jobs: Vec<(usize, Id, Subst)> = Vec::new();
        for (ri, rule) in rules.iter().enumerate() {
            let candidates: &Vec<Id> = match rule_tags[ri] {
                Some(tag) => by_tag.get(&tag).unwrap_or(&EMPTY),
                None => &all_classes,
            };
            for &root in candidates {
                for subst in super::ematch::ematch(eg, &rule.lhs, root) {
                    jobs.push((ri, root, subst));
                }
            }
        }
        // Phase 2: apply.
        let mut changed = false;
        for (ri, root, subst) in jobs {
            if eg.n_nodes > limits.max_nodes {
                stats.saturated = false;
                return stats;
            }
            let rule = &rules[ri];
            let equivs = (rule.apply)(eg, &subst, ctx);
            for id in equivs {
                match eg.union(root, id) {
                    Ok(true) => {
                        *stats.applied.entry(rule.name).or_insert(0) += 1;
                        changed = true;
                    }
                    Ok(false) => {}
                    Err(_) => {
                        // shape-mismatched union — a buggy lemma; skip but
                        // count nothing. Lemma validation catches these.
                    }
                }
            }
        }
        eg.rebuild();
        if !changed {
            return stats;
        }
    }
    stats.saturated = false;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::TensorRef;
    use crate::ir::{Op, OpTag};

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    /// add(x, y) -> sum(x, y): normalization rewrite used by the real
    /// lemma library.
    fn add_to_sum() -> Rewrite {
        Rewrite::new(
            "add_to_sum",
            Pat::exact(Op::Add, vec![Pat::var(0), Pat::var(1)]),
            |eg, s, _| {
                eg.add_op(Op::SumN, vec![s.var(0), s.var(1)]).into_iter().collect()
            },
        )
    }

    /// neg(neg(x)) -> x
    fn neg_involution() -> Rewrite {
        Rewrite::new(
            "neg_involution",
            Pat::exact(Op::Neg, vec![Pat::exact(Op::Neg, vec![Pat::var(0)])]),
            |_eg, s, _| vec![s.var(0)],
        )
    }

    #[test]
    fn saturation_finds_equivalence() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let add = eg.add_op(Op::Add, vec![a, b]).unwrap();
        let sum = eg.add_op(Op::SumN, vec![a, b]).unwrap();
        assert!(!eg.same(add, sum));
        let stats = saturate(&mut eg, &[add_to_sum()], &RewriteCtx::default(), Default::default());
        assert!(eg.same(add, sum));
        assert_eq!(stats.applied["add_to_sum"], 1);
        assert!(stats.saturated);
    }

    #[test]
    fn involution_collapses() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let n1 = eg.add_op(Op::Neg, vec![a]).unwrap();
        let n2 = eg.add_op(Op::Neg, vec![n1]).unwrap();
        saturate(&mut eg, &[neg_involution()], &RewriteCtx::default(), Default::default());
        assert!(eg.same(n2, a));
    }

    #[test]
    fn iteration_limit_respected() {
        // A rule that genuinely never saturates: every application unions a
        // brand-new leaf into the matched class (the unconstrained-rewrite
        // blowup §4.3.2 warns about).
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNTER: AtomicU32 = AtomicU32::new(1000);
        let grow = Rewrite::new(
            "grow",
            Pat::bind(OpTag::Neg, 0, vec![Pat::var(0)]),
            |eg, _s, _| {
                let fresh = COUNTER.fetch_add(1, Ordering::Relaxed);
                vec![eg.add_leaf(t(fresh), vec![4])]
            },
        );
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        eg.add_op(Op::Neg, vec![a]).unwrap();
        let stats = saturate(
            &mut eg,
            &[grow],
            &RewriteCtx::default(),
            SaturationLimits { max_iters: 3, max_nodes: 100_000 },
        );
        assert!(!stats.saturated);
        assert_eq!(stats.iterations, 3);
    }

    #[test]
    fn per_rule_counters() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let c = eg.add_leaf(t(2), vec![4]);
        eg.add_op(Op::Add, vec![a, b]).unwrap();
        eg.add_op(Op::Add, vec![b, c]).unwrap();
        let stats = saturate(&mut eg, &[add_to_sum()], &RewriteCtx::default(), Default::default());
        assert_eq!(stats.applied["add_to_sum"], 2);
        assert_eq!(stats.total_applications(), 2);
    }
}
