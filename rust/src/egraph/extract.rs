//! Clean-expression extraction.
//!
//! After saturation, each e-class holds many equivalent terms. The relation
//! inference needs, per class, the *clean* expressions (rearrangement +
//! reduction ops over allowed leaf tensors, §3.2) — and it needs several of
//! them: the running example keeps both `sum(C_1, C_2)` and
//! `concat(D_1, D_2)` for the same tensor, because either may pair with a
//! later operator's lemmas.
//!
//! We keep up to K candidates per class, at most one per distinct *leaf
//! signature* (sorted distinct leaf set). Candidates with the same leaf
//! signature are self-provably equivalent in the sense of §4.3.2 (their
//! equivalence is witnessed inside the e-graph without extra graph facts),
//! so keeping only the smallest of each signature is exactly the paper's
//! self-provable pruning.

use super::enode::{EGraph, ELang, Id};
use crate::expr::{Expr, TensorRef};
use rustc_hash::FxHashMap;

#[derive(Debug, Clone)]
pub struct CleanCand {
    pub expr: Expr,
    /// nested-op count (the paper's simplicity measure)
    pub cost: u32,
    /// sorted distinct leaves
    pub leaves: Vec<TensorRef>,
}

/// Max candidates kept per class.
pub const K_PER_CLASS: usize = 4;
/// Max child-combination expansions per enode per round.
const MAX_COMBOS: usize = 64;

/// Extract clean candidates for every class. `allowed` filters which leaf
/// tensors may appear (e.g. only `T_rel`, or only `O(G_d)` for the final
/// output relation).
pub fn extract_clean(
    eg: &EGraph,
    allowed: &dyn Fn(TensorRef) -> bool,
) -> FxHashMap<Id, Vec<CleanCand>> {
    let mut cands: FxHashMap<Id, Vec<CleanCand>> = FxHashMap::default();
    // Class ids sorted, not in hash-map order: with K_PER_CLASS eviction and
    // the MAX_COMBOS truncation below, the *visit order* can decide which of
    // two equal-cost signatures survives. Hash-map order depends on the
    // arena's capacity history (a reused `EGraph` lays out the same ids
    // differently than a fresh one), so sorting is what makes extraction a
    // deterministic function of the e-graph's logical content — the
    // invariant the fingerprint cache and the parallel walk rely on.
    let mut ids = eg.class_ids();
    ids.sort_unstable();
    // Fixpoint: classes gain candidates as their children do. Graphs here
    // are small (per-operator subproblems), so a simple loop suffices; the
    // round bound guards against cyclic classes.
    for _round in 0..24 {
        let mut changed = false;
        for &id in &ids {
            let class = eg.class(id);
            let mut fresh: Vec<CleanCand> = Vec::new();
            for node in &class.nodes {
                match &node.lang {
                    ELang::Leaf(t) => {
                        if allowed(*t) {
                            fresh.push(CleanCand {
                                expr: Expr::Leaf(*t),
                                cost: 0,
                                leaves: vec![*t],
                            });
                        }
                    }
                    ELang::Op(op) => {
                        if !op.is_clean() {
                            continue;
                        }
                        // all children must have candidates
                        let child_cands: Option<Vec<&Vec<CleanCand>>> = node
                            .children
                            .iter()
                            .map(|c| cands.get(&eg.find(*c)))
                            .collect();
                        let Some(child_cands) = child_cands else { continue };
                        if child_cands.iter().any(|v| v.is_empty()) {
                            continue;
                        }
                        combine(op.clone(), &child_cands, &mut fresh);
                    }
                }
            }
            if fresh.is_empty() {
                continue;
            }
            let entry = cands.entry(id).or_default();
            for cand in fresh {
                changed |= insert_cand(entry, cand);
            }
        }
        if !changed {
            break;
        }
    }
    cands
}

/// Candidate combination for one clean enode: cartesian over child
/// candidates, bounded.
fn combine(op: crate::ir::Op, children: &[&Vec<CleanCand>], out: &mut Vec<CleanCand>) {
    let mut combos: Vec<(Vec<Expr>, u32, Vec<TensorRef>)> = vec![(vec![], 1, vec![])];
    for child in children {
        let mut next = Vec::new();
        for (args, cost, leaves) in &combos {
            for cand in child.iter() {
                if next.len() >= MAX_COMBOS {
                    break;
                }
                let mut args2 = args.clone();
                args2.push(cand.expr.clone());
                let mut leaves2 = leaves.clone();
                leaves2.extend_from_slice(&cand.leaves);
                next.push((args2, cost + cand.cost, leaves2));
            }
        }
        combos = next;
        if combos.len() > MAX_COMBOS {
            combos.truncate(MAX_COMBOS);
        }
    }
    for (args, cost, mut leaves) in combos {
        leaves.sort();
        leaves.dedup();
        out.push(CleanCand { expr: Expr::Op(op.clone(), args), cost, leaves });
    }
}

/// Insert keeping ≤ K_PER_CLASS candidates, one per leaf signature (min
/// cost). Returns true if the set changed.
fn insert_cand(set: &mut Vec<CleanCand>, cand: CleanCand) -> bool {
    if let Some(existing) = set.iter_mut().find(|c| c.leaves == cand.leaves) {
        if cand.cost < existing.cost {
            *existing = cand;
            return true;
        }
        return false;
    }
    if set.len() < K_PER_CLASS {
        set.push(cand);
        set.sort_by_key(|c| c.cost);
        return true;
    }
    // evict the most expensive if strictly better
    if let Some(worst) = set.last() {
        if cand.cost < worst.cost {
            set.pop();
            set.push(cand);
            set.sort_by_key(|c| c.cost);
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Op;

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn extracts_leaf_and_clean_op() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![a, b]).unwrap();
        let cands = extract_clean(&eg, &|_| true);
        assert_eq!(cands[&a][0].cost, 0);
        let c = &cands[&cat];
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].cost, 1);
        assert_eq!(c[0].leaves, vec![t(0), t(1)]);
    }

    #[test]
    fn unclean_ops_are_skipped() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        let mm = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        let cands = extract_clean(&eg, &|_| true);
        assert!(!cands.contains_key(&mm), "matmul is not clean");
    }

    #[test]
    fn allowed_filter_prunes_leaves() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2]);
        let b = eg.add_leaf(t(1), vec![2]);
        let s = eg.add_op(Op::SumN, vec![a, b]).unwrap();
        // only t(0) allowed -> sum can't be built
        let cands = extract_clean(&eg, &|tr| tr == t(0));
        assert!(cands.contains_key(&a));
        assert!(!cands.contains_key(&b));
        assert!(!cands.contains_key(&s));
    }

    #[test]
    fn multiple_leaf_signatures_kept() {
        // class containing both sum(C1,C2) and concat(D1,D2):
        let mut eg = EGraph::new();
        let c1 = eg.add_leaf(t(0), vec![4, 4]);
        let c2 = eg.add_leaf(t(1), vec![4, 4]);
        let d1 = eg.add_leaf(t(2), vec![2, 4]);
        let d2 = eg.add_leaf(t(3), vec![2, 4]);
        let sum = eg.add_op(Op::SumN, vec![c1, c2]).unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![d1, d2]).unwrap();
        eg.union(sum, cat).unwrap();
        eg.rebuild();
        let cands = extract_clean(&eg, &|_| true);
        let got = &cands[&eg.find(sum)];
        assert_eq!(got.len(), 2, "both signatures: {:?}", got);
        let sigs: Vec<&Vec<TensorRef>> = got.iter().map(|c| &c.leaves).collect();
        assert!(sigs.contains(&&vec![t(0), t(1)]));
        assert!(sigs.contains(&&vec![t(2), t(3)]));
    }

    #[test]
    fn self_provable_pruning_keeps_smallest() {
        // same leaf signature, different size: slice(X,16..48) vs
        // concat(slice(X,16..32), slice(X,32..48)) — keep the former.
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![64]);
        let big = eg
            .add_op(Op::Slice { dim: 0, start: 16.into(), end: 48.into() }, vec![x])
            .unwrap();
        let l = eg
            .add_op(Op::Slice { dim: 0, start: 16.into(), end: 32.into() }, vec![x])
            .unwrap();
        let r = eg
            .add_op(Op::Slice { dim: 0, start: 32.into(), end: 48.into() }, vec![x])
            .unwrap();
        let cat = eg.add_op(Op::Concat { dim: 0 }, vec![l, r]).unwrap();
        eg.union(big, cat).unwrap();
        eg.rebuild();
        let cands = extract_clean(&eg, &|_| true);
        let got = &cands[&eg.find(big)];
        assert_eq!(got.len(), 1, "one signature -> one candidate");
        assert_eq!(got[0].cost, 1, "smallest representative wins");
    }

    #[test]
    fn nested_clean_chain() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4, 4]);
        let s = eg
            .add_op(Op::Slice { dim: 0, start: 0.into(), end: 2.into() }, vec![a])
            .unwrap();
        let tr = eg.add_op(Op::Transpose { perm: vec![1, 0] }, vec![s]).unwrap();
        let cands = extract_clean(&eg, &|_| true);
        assert_eq!(cands[&tr][0].cost, 2);
        assert!(cands[&tr][0].expr.is_clean());
    }
}
