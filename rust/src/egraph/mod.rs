//! Equality-saturation engine (the role `egg` plays in the paper, §4.2.2).
//!
//! The offline environment has no `egg` crate, so this is a from-scratch
//! e-graph: hash-consed e-nodes, union-find over e-classes, congruence
//! closure, conditional pattern rewrites, bounded saturation, and a
//! *clean-expression* extractor that implements the paper's self-provable
//! pruning (§4.3.2) by keeping only the cheapest candidate per distinct
//! leaf signature.
//!
//! The e-graph language is exactly the IR's [`Op`](crate::ir::Op) plus
//! tensor leaves, so expressions ([`crate::expr::Expr`]) insert and extract
//! without translation.

pub mod enode;
pub mod extract;
pub mod ematch;
pub mod rewrite;
pub mod unionfind;

pub use enode::{EClass, EGraph, ELang, ENode, Id};
pub use extract::CleanCand;
pub use ematch::{ematch, ematch_all, ematch_into, Children, POp, Pat, Subst};
pub use extract::extract_clean;
pub use rewrite::{saturate, saturate_full_rescan, saturate_with, MatchStrategy};
pub use rewrite::{Exhaustion, Rewrite, RewriteCtx, SatStats, SaturationLimits};
