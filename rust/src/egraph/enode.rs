//! E-nodes, e-classes and the core e-graph with hash-consing, congruence
//! closure, and a shape analysis (every e-class carries the tensor shape its
//! terms evaluate to; unions of shape-distinct classes are rejected — they
//! would indicate an unsound lemma).

use super::unionfind::UnionFind;
use crate::expr::{Expr, TensorRef};
use crate::ir::{Op, OpTag};
use anyhow::{bail, Result};
use rustc_hash::{FxHashMap, FxHashSet};

pub type Id = u32;

/// The e-graph language: IR operators over child classes, or tensor leaves.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ELang {
    Leaf(TensorRef),
    Op(Op),
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ENode {
    pub lang: ELang,
    pub children: Vec<Id>,
}

impl ENode {
    pub fn leaf(t: TensorRef) -> Self {
        ENode { lang: ELang::Leaf(t), children: vec![] }
    }
    pub fn op(op: Op, children: Vec<Id>) -> Self {
        ENode { lang: ELang::Op(op), children }
    }

    fn canonicalize(&self, uf: &UnionFind) -> ENode {
        ENode {
            lang: self.lang.clone(),
            children: self.children.iter().map(|&c| uf.find(c)).collect(),
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct EClass {
    pub nodes: Vec<ENode>,
    /// (parent enode, parent class) pairs for congruence repair.
    pub parents: Vec<(ENode, Id)>,
}

#[derive(Debug, Default)]
pub struct EGraph {
    uf: UnionFind,
    /// canonical id -> class data (non-canonical ids have empty slots).
    classes: FxHashMap<Id, EClass>,
    memo: FxHashMap<ENode, Id>,
    /// classes whose parents need congruence repair.
    dirty: Vec<Id>,
    /// shape analysis per canonical id.
    shapes: FxHashMap<Id, Vec<i64>>,
    /// Persistent op-tag index: tag -> canonical ids of classes holding at
    /// least one node with that tag. Maintained by `add_op`/`union` so the
    /// rewrite engine never rebuilds it per saturation iteration.
    tag_index: FxHashMap<OpTag, FxHashSet<Id>>,
    /// Classes created, grown by a union, or given a new parent node since
    /// the last [`EGraph::take_dirty_closure`] — the seed of the
    /// incremental-matching worklist.
    touched: FxHashSet<Id>,
    /// total enodes ever added (limit enforcement).
    pub n_nodes: usize,
}

impl EGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn find(&self, id: Id) -> Id {
        self.uf.find(id)
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn class(&self, id: Id) -> &EClass {
        &self.classes[&self.uf.find(id)]
    }

    pub fn class_ids(&self) -> Vec<Id> {
        self.classes.keys().copied().collect()
    }

    pub fn shape(&self, id: Id) -> Option<&[i64]> {
        self.shapes.get(&self.uf.find(id)).map(|v| v.as_slice())
    }

    /// Add a leaf with known shape.
    pub fn add_leaf(&mut self, t: TensorRef, shape: Vec<i64>) -> Id {
        let node = ENode::leaf(t);
        if let Some(&id) = self.memo.get(&node) {
            return self.uf.find(id);
        }
        self.new_class(node, shape)
    }

    /// Add an op node over existing classes; computes the shape analysis.
    /// Fails if the op is ill-shaped over its children.
    pub fn add_op(&mut self, op: Op, children: Vec<Id>) -> Result<Id> {
        let children: Vec<Id> = children.iter().map(|&c| self.uf.find(c)).collect();
        let node = ENode::op(op.clone(), children.clone());
        if let Some(&id) = self.memo.get(&node) {
            return Ok(self.uf.find(id));
        }
        let child_shapes: Vec<Vec<i64>> = children
            .iter()
            .map(|c| {
                self.shape(*c)
                    .map(|s| s.to_vec())
                    .ok_or_else(|| anyhow::anyhow!("child class without shape"))
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&[i64]> = child_shapes.iter().map(|s| s.as_slice()).collect();
        let shape = op.infer_shape(&refs, None)?;
        let id = self.new_class(node.clone(), shape);
        for &c in &children {
            if let Some(class) = self.classes.get_mut(&c) {
                class.parents.push((node.clone(), id));
            }
            // A new parent node can enable context-dependent rewrites rooted
            // at the child's *other* parents (e.g. sibling-slice merging), so
            // the child seeds the incremental worklist too.
            self.touched.insert(c);
        }
        Ok(id)
    }

    fn new_class(&mut self, node: ENode, shape: Vec<i64>) -> Id {
        let id = self.uf.make_set();
        if let ELang::Op(op) = &node.lang {
            self.tag_index.entry(op.tag()).or_default().insert(id);
        }
        self.touched.insert(id);
        self.memo.insert(node.clone(), id);
        self.classes.insert(id, EClass { nodes: vec![node], parents: vec![] });
        self.shapes.insert(id, shape);
        self.n_nodes += 1;
        id
    }

    /// Look up a node without inserting (drives *constrained lemmas*,
    /// §4.3.2: a rewrite only fires if its target already exists).
    pub fn lookup(&self, op: &Op, children: &[Id]) -> Option<Id> {
        let node = ENode::op(
            op.clone(),
            children.iter().map(|&c| self.uf.find(c)).collect(),
        );
        self.memo.get(&node).map(|&id| self.uf.find(id))
    }

    pub fn lookup_leaf(&self, t: TensorRef) -> Option<Id> {
        self.memo.get(&ENode::leaf(t)).map(|&id| self.uf.find(id))
    }

    /// Insert an expression tree; leaves must already exist (or carry shapes
    /// via `leaf_shape`).
    pub fn add_expr(
        &mut self,
        e: &Expr,
        leaf_shape: &dyn Fn(TensorRef) -> Option<Vec<i64>>,
    ) -> Result<Id> {
        match e {
            Expr::Leaf(t) => {
                if let Some(id) = self.lookup_leaf(*t) {
                    Ok(id)
                } else {
                    let shape = leaf_shape(*t)
                        .ok_or_else(|| anyhow::anyhow!("unknown shape for leaf {:?}", t))?;
                    Ok(self.add_leaf(*t, shape))
                }
            }
            Expr::Op(op, args) => {
                let children: Vec<Id> = args
                    .iter()
                    .map(|a| self.add_expr(a, leaf_shape))
                    .collect::<Result<_>>()?;
                self.add_op(op.clone(), children)
            }
        }
    }

    /// Merge two classes. Shape-distinct unions are rejected as unsound.
    pub fn union(&mut self, a: Id, b: Id) -> Result<bool> {
        let (ra, rb) = (self.uf.find(a), self.uf.find(b));
        if ra == rb {
            return Ok(false);
        }
        if let (Some(sa), Some(sb)) = (self.shapes.get(&ra), self.shapes.get(&rb)) {
            if sa != sb {
                bail!("union of shape-distinct classes {:?} vs {:?} — unsound rewrite", sa, sb);
            }
        }
        let (keep, drop) = self.uf.union(ra, rb).expect("distinct roots");
        let dropped = self.classes.remove(&drop).unwrap_or_default();
        self.shapes.remove(&drop);
        for node in &dropped.nodes {
            if let ELang::Op(op) = &node.lang {
                if let Some(set) = self.tag_index.get_mut(&op.tag()) {
                    set.remove(&drop);
                    set.insert(keep);
                }
            }
        }
        let kept = self.classes.get_mut(&keep).expect("kept class");
        kept.nodes.extend(dropped.nodes);
        kept.parents.extend(dropped.parents);
        self.dirty.push(keep);
        self.touched.insert(keep);
        Ok(true)
    }

    /// Drain the set of classes touched since the last call and return it
    /// closed under transitive parents — exactly the classes where a rewrite
    /// pattern could newly match after the intervening mutations. Ids are
    /// canonical.
    pub fn take_dirty_closure(&mut self) -> FxHashSet<Id> {
        let seed: Vec<Id> = self.touched.drain().collect();
        let mut out = FxHashSet::default();
        let mut stack: Vec<Id> = seed.into_iter().map(|i| self.uf.find(i)).collect();
        while let Some(id) = stack.pop() {
            if !out.insert(id) {
                continue;
            }
            if let Some(class) = self.classes.get(&id) {
                for &(_, pid) in &class.parents {
                    let pid = self.uf.find(pid);
                    if !out.contains(&pid) {
                        stack.push(pid);
                    }
                }
            }
        }
        out
    }

    /// Canonical ids of classes containing at least one node with `tag`
    /// (served from the persistent index — O(matches), not O(classes)).
    pub fn classes_with_tag(&self, tag: OpTag) -> impl Iterator<Item = Id> + '_ {
        self.tag_classes(tag).into_iter().flatten().copied()
    }

    /// The persistent tag-index entry for `tag`, if any class carries it.
    /// Exposed as a set so the matcher can intersect it with a worklist by
    /// iterating whichever side is smaller.
    pub fn tag_classes(&self, tag: OpTag) -> Option<&FxHashSet<Id>> {
        self.tag_index.get(&tag).filter(|s| !s.is_empty())
    }

    /// Clear all contents while keeping allocated capacity, so one `EGraph`
    /// arena (memo table, class map, union-find vector, tag sets) is reused
    /// across the per-operator inference walk instead of reallocated.
    pub fn reset(&mut self) {
        self.uf.clear();
        self.classes.clear();
        self.memo.clear();
        self.dirty.clear();
        self.shapes.clear();
        for set in self.tag_index.values_mut() {
            set.clear();
        }
        self.touched.clear();
        self.n_nodes = 0;
    }

    /// Restore congruence: parents of merged classes may now be equal.
    pub fn rebuild(&mut self) {
        while let Some(id) = self.dirty.pop() {
            let id = self.uf.find(id);
            let parents = match self.classes.get_mut(&id) {
                Some(c) => std::mem::take(&mut c.parents),
                None => continue,
            };
            let mut seen: FxHashMap<ENode, Id> = FxHashMap::default();
            let mut new_parents = Vec::with_capacity(parents.len());
            let mut pending: Vec<(Id, Id)> = Vec::new();
            for (node, pid) in parents {
                let canon = node.canonicalize(&self.uf);
                let pid = self.uf.find(pid);
                // re-memoize under the canonical key
                if let Some(&existing) = self.memo.get(&canon) {
                    let existing = self.uf.find(existing);
                    if existing != pid {
                        pending.push((existing, pid));
                    }
                } else {
                    self.memo.insert(canon.clone(), pid);
                }
                if let Some(&dup) = seen.get(&canon) {
                    if dup != pid {
                        pending.push((dup, pid));
                    }
                } else {
                    seen.insert(canon.clone(), pid);
                    new_parents.push((canon, pid));
                }
            }
            if let Some(c) = self.classes.get_mut(&id) {
                c.parents = new_parents;
            }
            for (a, b) in pending {
                // unions during rebuild share the same shape by construction
                let _ = self.union(a, b);
            }
        }
        // canonicalize node lists (cheap; keeps matching exact)
        let ids: Vec<Id> = self.classes.keys().copied().collect();
        for id in ids {
            if let Some(mut class) = self.classes.remove(&id) {
                let mut set: FxHashSet<ENode> = FxHashSet::default();
                class.nodes = class
                    .nodes
                    .drain(..)
                    .map(|n| n.canonicalize(&self.uf))
                    .filter(|n| set.insert(n.clone()))
                    .collect();
                self.classes.insert(id, class);
            }
        }
    }

    /// Are the two ids in the same class?
    pub fn same(&self, a: Id, b: Id) -> bool {
        self.uf.find(a) == self.uf.find(b)
    }

    /// Check the structural invariants the incremental engine relies on.
    /// Valid after a `rebuild`; used by the property tests. Checks:
    /// congruence closure (no canonical node appears in two classes), memo
    /// canonicalization, parent-index completeness, and tag-index
    /// consistency in both directions.
    pub fn debug_check_invariants(&self) -> Result<(), String> {
        let mut canon_owner: FxHashMap<ENode, Id> = FxHashMap::default();
        for (&id, class) in &self.classes {
            if self.uf.find(id) != id {
                return Err(format!("class key {id} is not canonical"));
            }
            if !self.shapes.contains_key(&id) {
                return Err(format!("class {id} has no shape"));
            }
            for node in &class.nodes {
                let canon = node.canonicalize(&self.uf);
                // congruence closure: a canonical node lives in one class
                if let Some(&other) = canon_owner.get(&canon) {
                    if other != id {
                        return Err(format!(
                            "congruence violated: {canon:?} in classes {other} and {id}"
                        ));
                    }
                } else {
                    canon_owner.insert(canon.clone(), id);
                }
                // memo canonicalization: the canonical key resolves here
                match self.memo.get(&canon) {
                    Some(&m) if self.uf.find(m) == id => {}
                    Some(&m) => {
                        return Err(format!(
                            "memo for {canon:?} resolves to {} not {id}",
                            self.uf.find(m)
                        ))
                    }
                    None => return Err(format!("memo misses canonical node {canon:?}")),
                }
                // tag index: class must be listed under the node's tag
                if let ELang::Op(op) = &canon.lang {
                    let listed = self
                        .tag_index
                        .get(&op.tag())
                        .is_some_and(|set| set.contains(&id));
                    if !listed {
                        return Err(format!(
                            "tag index misses class {id} for tag {:?}",
                            op.tag()
                        ));
                    }
                }
                // parent index: every child knows about this parent node
                for &ch in &canon.children {
                    let ch = self.uf.find(ch);
                    let covered = self.classes.get(&ch).is_some_and(|c| {
                        c.parents.iter().any(|(pn, pid)| {
                            self.uf.find(*pid) == id && pn.canonicalize(&self.uf) == canon
                        })
                    });
                    if !covered {
                        return Err(format!(
                            "parent index of class {ch} misses parent {canon:?} (class {id})"
                        ));
                    }
                }
            }
        }
        // tag index, reverse direction: every listed class is canonical and
        // really contains a node with the tag
        for (&tag, set) in &self.tag_index {
            for &id in set {
                if self.uf.find(id) != id {
                    return Err(format!("tag index holds stale id {id} for {tag:?}"));
                }
                let has = self.classes.get(&id).is_some_and(|c| {
                    c.nodes
                        .iter()
                        .any(|n| matches!(&n.lang, ELang::Op(op) if op.tag() == tag))
                });
                if !has {
                    return Err(format!("tag index lists class {id} without a {tag:?} node"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn hashcons_dedupes() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        let m1 = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        let m2 = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        assert_eq!(m1, m2);
        assert_eq!(eg.num_classes(), 3);
    }

    #[test]
    fn congruence_after_union() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        let c = eg.add_leaf(t(2), vec![2, 2]);
        let ac = eg.add_op(Op::Add, vec![a, c]).unwrap();
        let bc = eg.add_op(Op::Add, vec![b, c]).unwrap();
        assert!(!eg.same(ac, bc));
        eg.union(a, b).unwrap();
        eg.rebuild();
        assert!(eg.same(ac, bc), "congruence must merge add(a,c) and add(b,c)");
    }

    #[test]
    fn shape_mismatch_union_rejected() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![4]);
        assert!(eg.union(a, b).is_err());
    }

    #[test]
    fn lookup_does_not_insert() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        assert!(eg.lookup(&Op::Neg, &[a]).is_none());
        let n = eg.add_op(Op::Neg, vec![a]).unwrap();
        assert_eq!(eg.lookup(&Op::Neg, &[a]), Some(n));
    }

    #[test]
    fn add_expr_roundtrip() {
        use crate::expr::Expr;
        let mut eg = EGraph::new();
        let e = Expr::op(
            Op::Concat { dim: 0 },
            vec![Expr::leaf(t(0)), Expr::leaf(t(1))],
        );
        let shapes = |_tr: TensorRef| Some(vec![2, 3]);
        let id = eg.add_expr(&e, &shapes).unwrap();
        assert_eq!(eg.shape(id), Some(&[4, 3][..]));
    }

    #[test]
    fn tag_index_tracks_adds_and_unions() {
        use crate::ir::OpTag;
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let na = eg.add_op(Op::Neg, vec![a]).unwrap();
        let nb = eg.add_op(Op::Neg, vec![b]).unwrap();
        let negs: Vec<Id> = eg.classes_with_tag(OpTag::Neg).collect();
        assert_eq!(negs.len(), 2);
        eg.union(na, nb).unwrap();
        eg.rebuild();
        let negs: Vec<Id> = eg.classes_with_tag(OpTag::Neg).collect();
        assert_eq!(negs, vec![eg.find(na)], "merged class listed once");
        eg.debug_check_invariants().unwrap();
    }

    #[test]
    fn dirty_closure_covers_transitive_parents() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let n1 = eg.add_op(Op::Neg, vec![a]).unwrap();
        let n2 = eg.add_op(Op::Neg, vec![n1]).unwrap();
        // drain construction-time marks
        let _ = eg.take_dirty_closure();
        assert!(eg.take_dirty_closure().is_empty(), "no marks after drain");
        eg.union(a, b).unwrap();
        eg.rebuild();
        let w = eg.take_dirty_closure();
        let keep = eg.find(a);
        assert!(w.contains(&keep), "merged class in worklist");
        assert!(w.contains(&eg.find(n1)), "direct parent in worklist");
        assert!(w.contains(&eg.find(n2)), "transitive parent in worklist");
    }

    #[test]
    fn reset_reuses_arena() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        assert_eq!(eg.n_nodes, 3);
        eg.reset();
        assert_eq!(eg.n_nodes, 0);
        assert_eq!(eg.num_classes(), 0);
        // ids restart from zero and behave like a fresh graph
        let a2 = eg.add_leaf(t(0), vec![2, 2]);
        assert_eq!(a2, 0);
        let b2 = eg.add_leaf(t(1), vec![2, 2]);
        let m = eg.add_op(Op::MatMul, vec![a2, b2]).unwrap();
        assert_eq!(eg.lookup(&Op::MatMul, &[a2, b2]), Some(m));
        eg.debug_check_invariants().unwrap();
    }

    #[test]
    fn deep_congruence_chain() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2]);
        let b = eg.add_leaf(t(1), vec![2]);
        // neg(neg(neg(a))) vs neg(neg(neg(b)))
        let mut x = a;
        let mut y = b;
        for _ in 0..3 {
            x = eg.add_op(Op::Neg, vec![x]).unwrap();
            y = eg.add_op(Op::Neg, vec![y]).unwrap();
        }
        eg.union(a, b).unwrap();
        eg.rebuild();
        assert!(eg.same(x, y));
    }
}
