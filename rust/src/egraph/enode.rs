//! E-nodes, e-classes and the core e-graph with hash-consing, congruence
//! closure, and a shape analysis (every e-class carries the tensor shape its
//! terms evaluate to; unions of shape-distinct classes are rejected — they
//! would indicate an unsound lemma).

use super::unionfind::UnionFind;
use crate::expr::{Expr, TensorRef};
use crate::ir::Op;
use anyhow::{bail, Result};
use rustc_hash::{FxHashMap, FxHashSet};

pub type Id = u32;

/// The e-graph language: IR operators over child classes, or tensor leaves.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ELang {
    Leaf(TensorRef),
    Op(Op),
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ENode {
    pub lang: ELang,
    pub children: Vec<Id>,
}

impl ENode {
    pub fn leaf(t: TensorRef) -> Self {
        ENode { lang: ELang::Leaf(t), children: vec![] }
    }
    pub fn op(op: Op, children: Vec<Id>) -> Self {
        ENode { lang: ELang::Op(op), children }
    }

    fn canonicalize(&self, uf: &UnionFind) -> ENode {
        ENode {
            lang: self.lang.clone(),
            children: self.children.iter().map(|&c| uf.find(c)).collect(),
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct EClass {
    pub nodes: Vec<ENode>,
    /// (parent enode, parent class) pairs for congruence repair.
    pub parents: Vec<(ENode, Id)>,
}

#[derive(Debug, Default)]
pub struct EGraph {
    uf: UnionFind,
    /// canonical id -> class data (non-canonical ids have empty slots).
    classes: FxHashMap<Id, EClass>,
    memo: FxHashMap<ENode, Id>,
    /// classes whose parents need congruence repair.
    dirty: Vec<Id>,
    /// shape analysis per canonical id.
    shapes: FxHashMap<Id, Vec<i64>>,
    /// total enodes ever added (limit enforcement).
    pub n_nodes: usize,
}

impl EGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn find(&self, id: Id) -> Id {
        self.uf.find(id)
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn class(&self, id: Id) -> &EClass {
        &self.classes[&self.uf.find(id)]
    }

    pub fn class_ids(&self) -> Vec<Id> {
        self.classes.keys().copied().collect()
    }

    pub fn shape(&self, id: Id) -> Option<&[i64]> {
        self.shapes.get(&self.uf.find(id)).map(|v| v.as_slice())
    }

    /// Add a leaf with known shape.
    pub fn add_leaf(&mut self, t: TensorRef, shape: Vec<i64>) -> Id {
        let node = ENode::leaf(t);
        if let Some(&id) = self.memo.get(&node) {
            return self.uf.find(id);
        }
        let id = self.new_class(node, shape);
        id
    }

    /// Add an op node over existing classes; computes the shape analysis.
    /// Fails if the op is ill-shaped over its children.
    pub fn add_op(&mut self, op: Op, children: Vec<Id>) -> Result<Id> {
        let children: Vec<Id> = children.iter().map(|&c| self.uf.find(c)).collect();
        let node = ENode::op(op.clone(), children.clone());
        if let Some(&id) = self.memo.get(&node) {
            return Ok(self.uf.find(id));
        }
        let child_shapes: Vec<Vec<i64>> = children
            .iter()
            .map(|c| {
                self.shape(*c)
                    .map(|s| s.to_vec())
                    .ok_or_else(|| anyhow::anyhow!("child class without shape"))
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&[i64]> = child_shapes.iter().map(|s| s.as_slice()).collect();
        let shape = op.infer_shape(&refs, None)?;
        let id = self.new_class(node.clone(), shape);
        for &c in &children {
            if let Some(class) = self.classes.get_mut(&c) {
                class.parents.push((node.clone(), id));
            }
        }
        Ok(id)
    }

    fn new_class(&mut self, node: ENode, shape: Vec<i64>) -> Id {
        let id = self.uf.make_set();
        self.memo.insert(node.clone(), id);
        self.classes.insert(id, EClass { nodes: vec![node], parents: vec![] });
        self.shapes.insert(id, shape);
        self.n_nodes += 1;
        id
    }

    /// Look up a node without inserting (drives *constrained lemmas*,
    /// §4.3.2: a rewrite only fires if its target already exists).
    pub fn lookup(&self, op: &Op, children: &[Id]) -> Option<Id> {
        let node = ENode::op(
            op.clone(),
            children.iter().map(|&c| self.uf.find(c)).collect(),
        );
        self.memo.get(&node).map(|&id| self.uf.find(id))
    }

    pub fn lookup_leaf(&self, t: TensorRef) -> Option<Id> {
        self.memo.get(&ENode::leaf(t)).map(|&id| self.uf.find(id))
    }

    /// Insert an expression tree; leaves must already exist (or carry shapes
    /// via `leaf_shape`).
    pub fn add_expr(
        &mut self,
        e: &Expr,
        leaf_shape: &dyn Fn(TensorRef) -> Option<Vec<i64>>,
    ) -> Result<Id> {
        match e {
            Expr::Leaf(t) => {
                if let Some(id) = self.lookup_leaf(*t) {
                    Ok(id)
                } else {
                    let shape = leaf_shape(*t)
                        .ok_or_else(|| anyhow::anyhow!("unknown shape for leaf {:?}", t))?;
                    Ok(self.add_leaf(*t, shape))
                }
            }
            Expr::Op(op, args) => {
                let children: Vec<Id> = args
                    .iter()
                    .map(|a| self.add_expr(a, leaf_shape))
                    .collect::<Result<_>>()?;
                self.add_op(op.clone(), children)
            }
        }
    }

    /// Merge two classes. Shape-distinct unions are rejected as unsound.
    pub fn union(&mut self, a: Id, b: Id) -> Result<bool> {
        let (ra, rb) = (self.uf.find(a), self.uf.find(b));
        if ra == rb {
            return Ok(false);
        }
        if let (Some(sa), Some(sb)) = (self.shapes.get(&ra), self.shapes.get(&rb)) {
            if sa != sb {
                bail!("union of shape-distinct classes {:?} vs {:?} — unsound rewrite", sa, sb);
            }
        }
        let (keep, drop) = self.uf.union(ra, rb).expect("distinct roots");
        let dropped = self.classes.remove(&drop).unwrap_or_default();
        self.shapes.remove(&drop);
        let kept = self.classes.get_mut(&keep).expect("kept class");
        kept.nodes.extend(dropped.nodes);
        kept.parents.extend(dropped.parents);
        self.dirty.push(keep);
        Ok(true)
    }

    /// Restore congruence: parents of merged classes may now be equal.
    pub fn rebuild(&mut self) {
        while let Some(id) = self.dirty.pop() {
            let id = self.uf.find(id);
            let parents = match self.classes.get_mut(&id) {
                Some(c) => std::mem::take(&mut c.parents),
                None => continue,
            };
            let mut seen: FxHashMap<ENode, Id> = FxHashMap::default();
            let mut new_parents = Vec::with_capacity(parents.len());
            let mut pending: Vec<(Id, Id)> = Vec::new();
            for (node, pid) in parents {
                let canon = node.canonicalize(&self.uf);
                let pid = self.uf.find(pid);
                // re-memoize under the canonical key
                if let Some(&existing) = self.memo.get(&canon) {
                    let existing = self.uf.find(existing);
                    if existing != pid {
                        pending.push((existing, pid));
                    }
                } else {
                    self.memo.insert(canon.clone(), pid);
                }
                if let Some(&dup) = seen.get(&canon) {
                    if dup != pid {
                        pending.push((dup, pid));
                    }
                } else {
                    seen.insert(canon.clone(), pid);
                    new_parents.push((canon, pid));
                }
            }
            if let Some(c) = self.classes.get_mut(&id) {
                c.parents = new_parents;
            }
            for (a, b) in pending {
                // unions during rebuild share the same shape by construction
                let _ = self.union(a, b);
            }
        }
        // canonicalize node lists (cheap; keeps matching exact)
        let ids: Vec<Id> = self.classes.keys().copied().collect();
        for id in ids {
            if let Some(mut class) = self.classes.remove(&id) {
                let mut set: FxHashSet<ENode> = FxHashSet::default();
                class.nodes = class
                    .nodes
                    .drain(..)
                    .map(|n| n.canonicalize(&self.uf))
                    .filter(|n| set.insert(n.clone()))
                    .collect();
                self.classes.insert(id, class);
            }
        }
    }

    /// Are the two ids in the same class?
    pub fn same(&self, a: Id, b: Id) -> bool {
        self.uf.find(a) == self.uf.find(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn hashcons_dedupes() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        let m1 = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        let m2 = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        assert_eq!(m1, m2);
        assert_eq!(eg.num_classes(), 3);
    }

    #[test]
    fn congruence_after_union() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![2, 2]);
        let c = eg.add_leaf(t(2), vec![2, 2]);
        let ac = eg.add_op(Op::Add, vec![a, c]).unwrap();
        let bc = eg.add_op(Op::Add, vec![b, c]).unwrap();
        assert!(!eg.same(ac, bc));
        eg.union(a, b).unwrap();
        eg.rebuild();
        assert!(eg.same(ac, bc), "congruence must merge add(a,c) and add(b,c)");
    }

    #[test]
    fn shape_mismatch_union_rejected() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 2]);
        let b = eg.add_leaf(t(1), vec![4]);
        assert!(eg.union(a, b).is_err());
    }

    #[test]
    fn lookup_does_not_insert() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        assert!(eg.lookup(&Op::Neg, &[a]).is_none());
        let n = eg.add_op(Op::Neg, vec![a]).unwrap();
        assert_eq!(eg.lookup(&Op::Neg, &[a]), Some(n));
    }

    #[test]
    fn add_expr_roundtrip() {
        use crate::expr::Expr;
        let mut eg = EGraph::new();
        let e = Expr::op(
            Op::Concat { dim: 0 },
            vec![Expr::leaf(t(0)), Expr::leaf(t(1))],
        );
        let shapes = |_tr: TensorRef| Some(vec![2, 3]);
        let id = eg.add_expr(&e, &shapes).unwrap();
        assert_eq!(eg.shape(id), Some(&[4, 3][..]));
    }

    #[test]
    fn deep_congruence_chain() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2]);
        let b = eg.add_leaf(t(1), vec![2]);
        // neg(neg(neg(a))) vs neg(neg(neg(b)))
        let mut x = a;
        let mut y = b;
        for _ in 0..3 {
            x = eg.add_op(Op::Neg, vec![x]).unwrap();
            y = eg.add_op(Op::Neg, vec![y]).unwrap();
        }
        eg.union(a, b).unwrap();
        eg.rebuild();
        assert!(eg.same(x, y));
    }
}
