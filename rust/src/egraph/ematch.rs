//! E-matching: find all substitutions under which a pattern matches an
//! e-class. Patterns bind tensor-expression variables (`Var`), whole
//! operator attributes (`Bind`), and variadic child lists (`Children::
//! Variadic`) — the last is what lets one lemma cover concat/sum of any
//! parallelism degree.

use super::enode::{EGraph, ELang, ENode, Id};
use crate::ir::{Op, OpTag};

/// Operator matcher within a pattern node.
#[derive(Debug, Clone)]
pub enum POp {
    /// Exact operator (attributes included).
    Exact(Op),
    /// Any operator with this tag; the concrete op is bound to `slot`.
    Bind { tag: OpTag, slot: u32 },
    /// Any unary elementwise op, bound to `slot`.
    AnyUnaryEltwise { slot: u32 },
    /// Any binary elementwise op, bound to `slot`.
    AnyBinaryEltwise { slot: u32 },
}

#[derive(Debug, Clone)]
pub enum Children {
    Fixed(Vec<Pat>),
    /// Match any arity; bind the child class list to list-slot `slot`.
    Variadic { slot: u32 },
}

#[derive(Debug, Clone)]
pub enum Pat {
    /// Matches any class, binding it to var `slot` (consistently).
    Var(u32),
    Node { op: POp, children: Children },
}

impl Pat {
    pub fn var(slot: u32) -> Pat {
        Pat::Var(slot)
    }
    pub fn exact(op: Op, children: Vec<Pat>) -> Pat {
        Pat::Node { op: POp::Exact(op), children: Children::Fixed(children) }
    }
    pub fn bind(tag: OpTag, slot: u32, children: Vec<Pat>) -> Pat {
        Pat::Node { op: POp::Bind { tag, slot }, children: Children::Fixed(children) }
    }
    pub fn bind_variadic(tag: OpTag, slot: u32, list_slot: u32) -> Pat {
        Pat::Node { op: POp::Bind { tag, slot }, children: Children::Variadic { slot: list_slot } }
    }
    pub fn node(op: POp, children: Vec<Pat>) -> Pat {
        Pat::Node { op, children: Children::Fixed(children) }
    }
}

/// A substitution: tensor-expression vars, bound ops, and bound child lists.
#[derive(Debug, Clone, Default)]
pub struct Subst {
    pub vars: Vec<Option<Id>>,
    pub ops: Vec<Option<Op>>,
    pub lists: Vec<Option<Vec<Id>>>,
}

impl Subst {
    fn ensure(&mut self, nv: usize, no: usize, nl: usize) {
        if self.vars.len() < nv {
            self.vars.resize(nv, None);
        }
        if self.ops.len() < no {
            self.ops.resize(no, None);
        }
        if self.lists.len() < nl {
            self.lists.resize(nl, None);
        }
    }

    /// Bound tensor-expression var, or `None` if the slot is unbound.
    /// Appliers treat `None` as "rule does not fire" instead of panicking —
    /// a mis-slotted pattern then costs a skipped rewrite, not the whole
    /// verification.
    pub fn var(&self, slot: u32) -> Option<Id> {
        self.vars.get(slot as usize).copied().flatten()
    }
    /// Bound operator, or `None` if the slot is unbound.
    pub fn op(&self, slot: u32) -> Option<&Op> {
        self.ops.get(slot as usize).and_then(|o| o.as_ref())
    }
    /// Bound variadic child list, or `None` if the slot is unbound.
    pub fn list(&self, slot: u32) -> Option<&[Id]> {
        self.lists.get(slot as usize).and_then(|l| l.as_deref())
    }
}

/// Maximum substitutions per (rule, class) — guards pathological blowup.
const MAX_MATCHES_PER_CLASS: usize = 64;

/// Match `pat` against class `root`; return all substitutions.
pub fn ematch(eg: &EGraph, pat: &Pat, root: Id) -> Vec<Subst> {
    let mut out = Vec::new();
    ematch_into(eg, pat, root, &mut out);
    out
}

/// Like [`ematch`], but clears and fills a caller-provided buffer so the
/// saturation hot loop reuses one allocation across every (rule, class)
/// pair instead of building a fresh `Vec` per call.
pub fn ematch_into(eg: &EGraph, pat: &Pat, root: Id, out: &mut Vec<Subst>) {
    out.clear();
    let init = Subst::default();
    match_pat(eg, pat, eg.find(root), &init, out);
    out.truncate(MAX_MATCHES_PER_CLASS);
}

/// Match `pat` against every class in the graph; returns (root, subst).
pub fn ematch_all(eg: &EGraph, pat: &Pat) -> Vec<(Id, Subst)> {
    let mut out = Vec::new();
    for id in eg.class_ids() {
        for s in ematch(eg, pat, id) {
            out.push((id, s));
        }
    }
    out
}

fn match_pat(eg: &EGraph, pat: &Pat, class: Id, subst: &Subst, out: &mut Vec<Subst>) {
    if out.len() >= MAX_MATCHES_PER_CLASS {
        return;
    }
    match pat {
        Pat::Var(slot) => {
            let mut s = subst.clone();
            s.ensure(*slot as usize + 1, 0, 0);
            match s.vars[*slot as usize] {
                Some(bound) if eg.find(bound) != class => {} // inconsistent
                _ => {
                    s.vars[*slot as usize] = Some(class);
                    out.push(s);
                }
            }
        }
        Pat::Node { op, children } => {
            for node in &eg.class(class).nodes {
                if let Some(s2) = match_op(op, node, subst) {
                    match children {
                        Children::Fixed(pats) => {
                            if pats.len() != node.children.len() {
                                continue;
                            }
                            match_children(eg, pats, &node.children, &s2, out);
                        }
                        Children::Variadic { slot } => {
                            let mut s3 = s2.clone();
                            s3.ensure(0, 0, *slot as usize + 1);
                            match &s3.lists[*slot as usize] {
                                Some(bound)
                                    if bound.len() != node.children.len()
                                        || bound
                                            .iter()
                                            .zip(&node.children)
                                            .any(|(&a, &b)| eg.find(a) != eg.find(b)) => {}
                                _ => {
                                    s3.lists[*slot as usize] = Some(node.children.clone());
                                    out.push(s3);
                                }
                            }
                        }
                    }
                }
                if out.len() >= MAX_MATCHES_PER_CLASS {
                    return;
                }
            }
        }
    }
}

fn match_children(eg: &EGraph, pats: &[Pat], children: &[Id], subst: &Subst, out: &mut Vec<Subst>) {
    // depth-first product of per-child matches, with consistent bindings
    fn rec(
        eg: &EGraph,
        pats: &[Pat],
        children: &[Id],
        i: usize,
        subst: &Subst,
        out: &mut Vec<Subst>,
    ) {
        if out.len() >= MAX_MATCHES_PER_CLASS {
            return;
        }
        if i == pats.len() {
            out.push(subst.clone());
            return;
        }
        let mut partial = Vec::new();
        match_pat(eg, &pats[i], eg.find(children[i]), subst, &mut partial);
        for s in partial {
            rec(eg, pats, children, i + 1, &s, out);
        }
    }
    rec(eg, pats, children, 0, subst, out);
}

fn match_op(pop: &POp, node: &ENode, subst: &Subst) -> Option<Subst> {
    let op = match &node.lang {
        ELang::Op(op) => op,
        ELang::Leaf(_) => return None,
    };
    match pop {
        POp::Exact(want) => (op == want).then(|| subst.clone()),
        POp::Bind { tag, slot } => (op.tag() == *tag).then(|| {
            let mut s = subst.clone();
            s.ensure(0, *slot as usize + 1, 0);
            s.ops[*slot as usize] = Some(op.clone());
            s
        }),
        POp::AnyUnaryEltwise { slot } => op.is_unary_elementwise().then(|| {
            let mut s = subst.clone();
            s.ensure(0, *slot as usize + 1, 0);
            s.ops[*slot as usize] = Some(op.clone());
            s
        }),
        POp::AnyBinaryEltwise { slot } => op.is_binary_elementwise().then(|| {
            let mut s = subst.clone();
            s.ensure(0, *slot as usize + 1, 0);
            s.ops[*slot as usize] = Some(op.clone());
            s
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::TensorRef;

    fn t(i: u32) -> TensorRef {
        TensorRef::d(i)
    }

    #[test]
    fn match_exact_matmul() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 3]);
        let b = eg.add_leaf(t(1), vec![3, 2]);
        let m = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        let pat = Pat::exact(Op::MatMul, vec![Pat::var(0), Pat::var(1)]);
        let subs = ematch(&eg, &pat, m);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].var(0), Some(a));
        assert_eq!(subs[0].var(1), Some(b));
        assert_eq!(subs[0].var(2), None, "unbound slot is a graceful None");
        // no match against a leaf class
        assert!(ematch(&eg, &pat, a).is_empty());
    }

    #[test]
    fn bind_op_attrs() {
        let mut eg = EGraph::new();
        let x = eg.add_leaf(t(0), vec![8]);
        let s = eg
            .add_op(Op::Slice { dim: 0, start: 2.into(), end: 6.into() }, vec![x])
            .unwrap();
        let pat = Pat::bind(OpTag::Slice, 0, vec![Pat::var(0)]);
        let subs = ematch(&eg, &pat, s);
        assert_eq!(subs.len(), 1);
        match subs[0].op(0).unwrap() {
            Op::Slice { start, end, .. } => {
                assert_eq!(start.as_const(), Some(2));
                assert_eq!(end.as_const(), Some(6));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn variadic_concat() {
        let mut eg = EGraph::new();
        let parts: Vec<Id> = (0..3).map(|i| eg.add_leaf(t(i), vec![2, 4])).collect();
        let c = eg.add_op(Op::Concat { dim: 0 }, parts.clone()).unwrap();
        let pat = Pat::bind_variadic(OpTag::Concat, 0, 0);
        let subs = ematch(&eg, &pat, c);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].list(0), Some(&parts[..]));
    }

    #[test]
    fn consistent_var_binding() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let aa = eg.add_op(Op::Add, vec![a, a]).unwrap();
        let ab = eg.add_op(Op::Add, vec![a, b]).unwrap();
        // pattern add(x, x) must match add(a,a) but not add(a,b)
        let pat = Pat::exact(Op::Add, vec![Pat::var(0), Pat::var(0)]);
        assert_eq!(ematch(&eg, &pat, aa).len(), 1);
        assert!(ematch(&eg, &pat, ab).is_empty());
    }

    #[test]
    fn nested_pattern() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![2, 3]);
        let b = eg.add_leaf(t(1), vec![3, 2]);
        let m = eg.add_op(Op::MatMul, vec![a, b]).unwrap();
        let n = eg.add_op(Op::Neg, vec![m]).unwrap();
        let pat = Pat::exact(
            Op::Neg,
            vec![Pat::exact(Op::MatMul, vec![Pat::var(0), Pat::var(1)])],
        );
        let subs = ematch(&eg, &pat, n);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].var(0), Some(a));
    }

    #[test]
    fn matches_across_merged_classes() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(t(0), vec![4]);
        let b = eg.add_leaf(t(1), vec![4]);
        let na = eg.add_op(Op::Neg, vec![a]).unwrap();
        eg.union(na, b).unwrap();
        eg.rebuild();
        // b's class now contains neg(a); pattern neg(x) must match it
        let pat = Pat::exact(Op::Neg, vec![Pat::var(0)]);
        let subs = ematch(&eg, &pat, b);
        assert_eq!(subs.len(), 1);
    }
}
