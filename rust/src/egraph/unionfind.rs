//! Union-find with path halving. Ids are dense `u32`s allocated by the
//! e-graph.

use super::enode::Id;

#[derive(Debug, Default, Clone)]
pub struct UnionFind {
    parent: Vec<Id>,
}

impl UnionFind {
    pub fn make_set(&mut self) -> Id {
        let id = self.parent.len() as Id;
        self.parent.push(id);
        id
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// Forget all sets but keep the allocation (arena reuse).
    pub fn clear(&mut self) {
        self.parent.clear();
    }
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    pub fn find(&self, mut x: Id) -> Id {
        while self.parent[x as usize] != x {
            x = self.parent[x as usize];
        }
        x
    }

    /// find with path-halving (mutable fast path).
    pub fn find_mut(&mut self, mut x: Id) -> Id {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Union two sets; returns (new_root, merged_away) or None if already
    /// one set. The smaller id wins — deterministic canonical ids.
    pub fn union(&mut self, a: Id, b: Id) -> Option<(Id, Id)> {
        let ra = self.find_mut(a);
        let rb = self.find_mut(b);
        if ra == rb {
            return None;
        }
        let (keep, drop) = if ra < rb { (ra, rb) } else { (rb, ra) };
        self.parent[drop as usize] = keep;
        Some((keep, drop))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_union_find() {
        let mut uf = UnionFind::default();
        let a = uf.make_set();
        let b = uf.make_set();
        let c = uf.make_set();
        assert_ne!(uf.find(a), uf.find(b));
        assert_eq!(uf.union(a, b), Some((a, b)));
        assert_eq!(uf.find(b), a);
        assert_eq!(uf.union(b, a), None);
        uf.union(b, c);
        assert_eq!(uf.find(c), a);
    }

    #[test]
    fn canonical_is_smallest_id() {
        let mut uf = UnionFind::default();
        let ids: Vec<Id> = (0..10).map(|_| uf.make_set()).collect();
        for w in ids.windows(2) {
            uf.union(w[1], w[0]);
        }
        for &i in &ids {
            assert_eq!(uf.find(i), ids[0]);
        }
    }
}
