//! HLO-text frontend (paper §5.1).
//!
//! The paper demonstrates framework-independence by checking a
//! Transformers-NeuronX Llama-3 whose graphs come from XLA HLO, via a small
//! translation utility. This module is that utility for our stack: it
//! parses the HLO text JAX emits (the same artifacts the PJRT runtime
//! executes) into the graph IR, covering the instruction subset our models
//! lower to. Scalar `constant`+`broadcast` chains fold into
//! `Scale`/`AddScalar` attrs; `custom-call`s map to `Op::Custom` so users
//! can attach lemmas (§6.5, "h"-group).

// This module parses untrusted input (HLO text from arbitrary toolchains):
// malformed input must surface as `Err`, never a panic. Enforced via
// `disallowed-methods` in clippy.toml (unwrap/expect banned).
#![deny(clippy::disallowed_methods)]

use crate::ir::{DType, FBits, Graph, Op, TensorId};
use anyhow::{anyhow, bail, Context, Result};
use rustc_hash::FxHashMap;

/// Parse the ENTRY computation of an HLO-text module into a [`Graph`].
pub fn parse_hlo_text(text: &str, name: &str) -> Result<Graph> {
    let entry = extract_entry(text)?;
    let mut g = Graph::new(name);
    // per-instruction bookkeeping
    let mut ids: FxHashMap<String, TensorId> = FxHashMap::default();
    let mut scalar_consts: FxHashMap<String, f64> = FxHashMap::default();
    let mut root: Option<String> = None;
    let mut tuple_elems: FxHashMap<String, Vec<String>> = FxHashMap::default();

    for raw in entry {
        let line = raw.trim().trim_end_matches(',');
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        let inst = parse_instruction(line).with_context(|| format!("parsing '{line}'"))?;
        if inst.is_root {
            root = Some(inst.name.clone());
        }
        match inst.opcode.as_str() {
            "parameter" => {
                let id = g.input_typed(&inst.name, inst.shape.clone(), DType::F32);
                ids.insert(inst.name.clone(), id);
            }
            "constant" => {
                if inst.shape.is_empty() {
                    let v: f64 = inst
                        .payload
                        .as_deref()
                        .unwrap_or("0")
                        .parse()
                        .map_err(|_| anyhow!("bad constant payload"))?;
                    scalar_consts.insert(inst.name.clone(), v);
                } else {
                    // non-scalar constants become graph inputs (weights
                    // embedded in the module)
                    let id = g.input_typed(&inst.name, inst.shape.clone(), DType::F32);
                    ids.insert(inst.name.clone(), id);
                }
            }
            "broadcast" => {
                // broadcast of a scalar const stays a scalar alias;
                // broadcast of a tensor is handled as identity when shapes
                // allow (JAX emits it for bias adds — our binary ops
                // broadcast natively)
                let src = inst
                    .operands
                    .first()
                    .ok_or_else(|| anyhow!("broadcast '{}' has no operand", inst.name))?;
                if let Some(&v) = scalar_consts.get(src) {
                    scalar_consts.insert(inst.name.clone(), v);
                } else if let Some(&t) = ids.get(src) {
                    ids.insert(inst.name.clone(), t);
                } else {
                    bail!("broadcast of unknown operand {src}");
                }
            }
            "tuple" => {
                tuple_elems.insert(inst.name.clone(), inst.operands.clone());
            }
            op => {
                let out = lower_op(&mut g, op, &inst, &ids, &scalar_consts)?;
                ids.insert(inst.name.clone(), out);
            }
        }
    }

    let root = root.ok_or_else(|| anyhow!("no ROOT instruction"))?;
    let outputs: Vec<String> = tuple_elems.remove(&root).unwrap_or_else(|| vec![root.clone()]);
    for out in outputs {
        let id = *ids.get(&out).ok_or_else(|| anyhow!("unknown output '{out}'"))?;
        g.mark_output(id);
    }
    g.validate()?;
    Ok(g)
}

fn lower_op(
    g: &mut Graph,
    op: &str,
    inst: &Instruction,
    ids: &FxHashMap<String, TensorId>,
    scalars: &FxHashMap<String, f64>,
) -> Result<TensorId> {
    let t = |name: &String| -> Result<TensorId> {
        ids.get(name).copied().ok_or_else(|| anyhow!("unknown operand '{name}'"))
    };
    // Checked operand access: HLO text is untrusted input, so a truncated
    // operand list must surface as a parse error, never an index panic.
    let operand = |i: usize| -> Result<&String> {
        inst.operands.get(i).ok_or_else(|| {
            anyhow!(
                "'{}' ({op}) needs operand #{} but has {}",
                inst.name,
                i,
                inst.operands.len()
            )
        })
    };
    let name = inst.name.as_str();
    Ok(match op {
        "add" | "subtract" | "multiply" | "divide" | "maximum" => {
            // scalar-const operand folds into Scale / AddScalar
            let (a, b) = (operand(0)?, operand(1)?);
            match (scalars.get(a), scalars.get(b)) {
                (None, Some(&c)) | (Some(&c), None) => {
                    let tensor = if scalars.contains_key(a) { t(b)? } else { t(a)? };
                    match op {
                        "add" => g.add(name, Op::AddScalar { c: FBits::new(c) }, vec![tensor])?,
                        "subtract" if scalars.contains_key(b) => {
                            g.add(name, Op::AddScalar { c: FBits::new(-c) }, vec![tensor])?
                        }
                        "multiply" => g.add(name, Op::Scale { c: FBits::new(c) }, vec![tensor])?,
                        "divide" if scalars.contains_key(b) => {
                            g.add(name, Op::Scale { c: FBits::new(1.0 / c) }, vec![tensor])?
                        }
                        _ => bail!("unsupported scalar-fold for {op}"),
                    }
                }
                _ => {
                    let bin = match op {
                        "add" => Op::Add,
                        "subtract" => Op::Sub,
                        "multiply" => Op::Mul,
                        "divide" => Op::Div,
                        _ => Op::Maximum,
                    };
                    g.add(name, bin, vec![t(a)?, t(b)?])?
                }
            }
        }
        "negate" => g.add(name, Op::Neg, vec![t(operand(0)?)?])?,
        "exponential" => g.add(name, Op::Exp, vec![t(operand(0)?)?])?,
        "log" => g.add(name, Op::Log, vec![t(operand(0)?)?])?,
        "tanh" => g.add(name, Op::Tanh, vec![t(operand(0)?)?])?,
        "sqrt" => g.add(name, Op::Sqrt, vec![t(operand(0)?)?])?,
        "rsqrt" => g.add(name, Op::Rsqrt, vec![t(operand(0)?)?])?,
        "logistic" => g.add(name, Op::Sigmoid, vec![t(operand(0)?)?])?,
        "dot" => g.add(name, Op::MatMul, vec![t(operand(0)?)?, t(operand(1)?)?])?,
        "transpose" => {
            let perm = inst
                .attr_list("dimensions")
                .ok_or_else(|| anyhow!("transpose without dimensions"))?;
            g.add(
                name,
                Op::Transpose { perm: perm.iter().map(|&d| d as usize).collect() },
                vec![t(operand(0)?)?],
            )?
        }
        "reshape" => g.add(
            name,
            Op::Reshape { shape: inst.shape.iter().map(|&d| d.into()).collect() },
            vec![t(operand(0)?)?],
        )?,
        "concatenate" => {
            let dim = inst
                .attr_list("dimensions")
                .and_then(|v| v.first().copied())
                .ok_or_else(|| anyhow!("concatenate without dimensions"))?;
            let parts: Vec<TensorId> =
                inst.operands.iter().map(t).collect::<Result<_>>()?;
            g.add(name, Op::Concat { dim: dim as usize }, parts)?
        }
        "slice" => {
            // slice={[a:b],[c:d]}: chain per-dim slices where range != full
            let ranges = inst
                .slice_ranges
                .as_ref()
                .ok_or_else(|| anyhow!("slice without ranges"))?;
            let mut cur = t(operand(0)?)?;
            let rank = g.shape(cur).len();
            if ranges.len() > rank {
                bail!("slice '{name}': {} ranges on a rank-{rank} operand", ranges.len());
            }
            for (dim, &(a, b)) in ranges.iter().enumerate() {
                if a < 0 || b < a {
                    bail!("slice '{name}': bad range [{a}:{b}] in dim {dim}");
                }
                if g.shape(cur)[dim] != b - a {
                    cur = g.add(
                        &format!("{name}.d{dim}"),
                        Op::Slice { dim, start: a.into(), end: b.into() },
                        vec![cur],
                    )?;
                }
            }
            g.add(name, Op::Identity, vec![cur])?
        }
        "reduce" => {
            let mut dims = inst
                .attr_list("dimensions")
                .ok_or_else(|| anyhow!("reduce without dimensions"))?;
            let mut cur = t(operand(0)?)?;
            // sorted + deduped so the removed-axis adjustment below cannot
            // underflow on unsorted or repeated input dimensions
            dims.sort_unstable();
            dims.dedup();
            let rank = g.shape(cur).len() as i64;
            if let Some(&d) = dims.iter().find(|&&d| d < 0 || d >= rank) {
                bail!("reduce '{name}': dimension {d} out of range for rank {rank}");
            }
            let mut removed = 0usize;
            for &d in &dims {
                cur = g.add(
                    &format!("{name}.d{d}"),
                    Op::ReduceSum { dim: d as usize - removed, keepdim: false },
                    vec![cur],
                )?;
                removed += 1;
            }
            g.add(name, Op::Identity, vec![cur])?
        }
        "custom-call" => {
            let target = inst
                .custom_target
                .clone()
                .unwrap_or_else(|| "unknown_custom".to_string());
            let parts: Vec<TensorId> =
                inst.operands.iter().map(t).collect::<Result<_>>()?;
            g.add(name, Op::Custom { name: target }, parts)?
        }
        "copy" | "convert" | "bitcast" => g.add(name, Op::Identity, vec![t(operand(0)?)?])?,
        other => bail!(
            "unsupported HLO opcode '{other}' at instruction '{}'{} — add a lemma/op \
             mapping (§6.5)",
            inst.name,
            suggest_opcodes(other)
        ),
    })
}

/// Every opcode `lower_op` (or the frontend's pre-pass) accepts, for
/// unknown-opcode diagnostics.
const KNOWN_OPCODES: &[&str] = &[
    "add", "bitcast", "broadcast", "concatenate", "constant", "convert", "copy",
    "custom-call", "divide", "dot", "exponential", "log", "logistic", "maximum",
    "multiply", "negate", "parameter", "reduce", "reshape", "rsqrt", "slice",
    "sqrt", "subtract", "tanh", "transpose", "tuple",
];

/// ` (did you mean ...?)` listing known opcodes sharing a prefix with the
/// unknown one (e.g. a truncated `exponen` or a versioned `reduce-window`),
/// or empty when nothing is close.
fn suggest_opcodes(unknown: &str) -> String {
    let pfx = |a: &str, b: &str| a.chars().zip(b.chars()).take_while(|(x, y)| x == y).count();
    let mut near: Vec<&str> = KNOWN_OPCODES
        .iter()
        .copied()
        .filter(|k| pfx(k, unknown) >= 3.min(k.len()).min(unknown.len()).max(2))
        .collect();
    near.truncate(3);
    if near.is_empty() {
        String::new()
    } else {
        format!(" (did you mean {}?)", near.join(", "))
    }
}

struct Instruction {
    name: String,
    opcode: String,
    shape: Vec<i64>,
    operands: Vec<String>,
    is_root: bool,
    payload: Option<String>,
    attrs: FxHashMap<String, String>,
    slice_ranges: Option<Vec<(i64, i64)>>,
    custom_target: Option<String>,
}

impl Instruction {
    fn attr_list(&self, key: &str) -> Option<Vec<i64>> {
        let raw = self.attrs.get(key)?;
        Some(
            raw.trim_matches(|c| c == '{' || c == '}')
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        )
    }
}

fn extract_entry(text: &str) -> Result<Vec<&str>> {
    let mut in_entry = false;
    let mut out = Vec::new();
    for line in text.lines() {
        let lt = line.trim();
        if lt.starts_with("ENTRY") {
            in_entry = true;
            continue;
        }
        if in_entry {
            if lt == "}" {
                return Ok(out);
            }
            out.push(line);
        }
    }
    bail!("no ENTRY computation found")
}

fn parse_instruction(line: &str) -> Result<Instruction> {
    // form: [ROOT] name = type opcode(operands), attr={...}, ...
    let (lhs, rhs) = line.split_once('=').ok_or_else(|| anyhow!("no '='"))?;
    let mut lhs = lhs.trim();
    let is_root = lhs.starts_with("ROOT ");
    if is_root {
        lhs = &lhs[5..];
    }
    let name = lhs.trim().to_string();
    let rhs = rhs.trim();
    // type: up to first space that follows the closing bracket/paren of type
    let (ty, rest) = split_type(rhs)?;
    let shape = parse_shape(ty)?;
    let paren = rest.find('(').ok_or_else(|| anyhow!("no opcode args"))?;
    let opcode = rest[..paren].trim().to_string();
    let close = matching_paren(rest, paren)?;
    let args_raw = &rest[paren + 1..close];
    let tail = &rest[close + 1..];

    let mut operands = Vec::new();
    let mut payload = None;
    if opcode == "constant" {
        payload = Some(args_raw.trim().to_string());
    } else {
        for a in split_top_level(args_raw) {
            let a = a.trim();
            if a.is_empty() {
                continue;
            }
            // operands may carry inline types: "f32[2,2]{1,0} name" or just "name"
            let operand = a.rsplit(' ').next().unwrap_or(a).trim().to_string();
            operands.push(operand);
        }
    }

    let mut attrs = FxHashMap::default();
    let mut slice_ranges = None;
    let mut custom_target = None;
    for part in split_top_level(tail) {
        let part = part.trim();
        if let Some((k, v)) = part.split_once('=') {
            let k = k.trim();
            let v = v.trim();
            if k == "slice" {
                // {[a:b], [c:d]}
                let mut ranges = Vec::new();
                for r in v.trim_matches(|c| c == '{' || c == '}').split("],") {
                    let r = r.trim().trim_matches(|c| c == '[' || c == ']');
                    if let Some((a, b)) = r.split_once(':') {
                        let a: i64 = a.trim().parse().unwrap_or(0);
                        // strides like a:b:s — take the bound before stride
                        let b: i64 = b.split(':').next().unwrap_or("0").trim().parse().unwrap_or(0);
                        ranges.push((a, b));
                    }
                }
                slice_ranges = Some(ranges);
            } else if k == "custom_call_target" {
                custom_target = Some(v.trim_matches('"').to_string());
            } else {
                attrs.insert(k.to_string(), v.to_string());
            }
        }
    }
    Ok(Instruction {
        name,
        opcode,
        shape,
        operands,
        is_root,
        payload,
        attrs,
        slice_ranges,
        custom_target,
    })
}

fn split_type(rhs: &str) -> Result<(&str, &str)> {
    // type ends at the space before the opcode; types may contain (),{}
    // e.g. "(f32[2,2]{1,0})" for tuples or "f32[] "
    let bytes = rhs.as_bytes();
    let mut depth = 0i32;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b' ' if depth == 0 => return Ok((&rhs[..i], &rhs[i + 1..])),
            _ => {}
        }
    }
    bail!("cannot split type from '{rhs}'")
}

fn parse_shape(ty: &str) -> Result<Vec<i64>> {
    // f32[4,2]{1,0} or (f32[..]) tuple (shape of first elem; ROOT tuples
    // don't need their own shape)
    let ty = ty.trim_start_matches('(');
    let Some(open) = ty.find('[') else { return Ok(vec![]) };
    let close = ty[open..].find(']').ok_or_else(|| anyhow!("bad type '{ty}'"))? + open;
    let inner = &ty[open + 1..close];
    if inner.trim().is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|d| d.trim().parse::<i64>().map_err(|_| anyhow!("bad dim '{d}'")))
        .collect()
}

fn matching_paren(s: &str, open: usize) -> Result<usize> {
    let mut depth = 0i32;
    for (i, b) in s.bytes().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(i);
                }
            }
            _ => {}
        }
    }
    bail!("unbalanced parens")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, b) in s.bytes().enumerate() {
        match b {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests panic on failure by design
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule jit_fn, entry_computation_layout={(f32[2,3]{1,0}, f32[3,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.7 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  Arg_1.2 = f32[3,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.4 = f32[] constant(2)
  broadcast.5 = f32[2,2]{1,0} broadcast(constant.4), dimensions={}
  add.6 = f32[2,2]{1,0} add(dot.3, broadcast.5)
  ROOT tuple.7 = (f32[2,2]{1,0}) tuple(add.6)
}
"#;

    #[test]
    fn parses_matmul_plus_constant() {
        let g = parse_hlo_text(SAMPLE, "sample").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.shape(g.outputs[0]), &[2, 2]);
        // add-with-scalar folded into AddScalar
        let out_node = g.producer(g.outputs[0]).unwrap();
        assert!(matches!(out_node.op, Op::AddScalar { .. }), "{:?}", out_node.op);
    }

    #[test]
    fn parsed_graph_evaluates_like_the_formula() {
        use crate::expr::eval::eval_graph;
        use crate::util::ndarray::NdArray;
        let g = parse_hlo_text(SAMPLE, "sample").unwrap();
        let mut env = rustc_hash::FxHashMap::default();
        env.insert(g.inputs[0], NdArray::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        env.insert(g.inputs[1], NdArray::full(vec![3, 2], 1.0));
        let vals = eval_graph(&g, &env).unwrap();
        // rows sum + 2
        assert_eq!(vals[g.outputs[0] as usize].data(), &[8., 8., 17., 17.]);
    }

    #[test]
    fn parses_structural_ops() {
        let text = r#"HloModule m

ENTRY e {
  p0 = f32[4,6]{1,0} parameter(0)
  t = f32[6,4]{1,0} transpose(p0), dimensions={1,0}
  s = f32[2,4]{1,0} slice(t), slice={[1:3], [0:4]}
  c = f32[4,4]{1,0} concatenate(s, s), dimensions={0}
  r = f32[16]{0} reshape(c)
  ROOT out = (f32[16]{0}) tuple(r)
}
"#;
        let g = parse_hlo_text(text, "structural").unwrap();
        assert_eq!(g.shape(g.outputs[0]), &[16]);
    }

    #[test]
    fn custom_call_maps_to_custom_op() {
        let text = r#"HloModule m

ENTRY e {
  p0 = f32[2,8]{1,0} parameter(0)
  p1 = f32[8]{0} parameter(1)
  cc = f32[2,8]{1,0} custom-call(p0, p1), custom_call_target="pallas_rms_norm"
  ROOT out = (f32[2,8]{1,0}) tuple(cc)
}
"#;
        let g = parse_hlo_text(text, "custom").unwrap();
        let node = g.producer(g.outputs[0]).unwrap();
        assert!(matches!(&node.op, Op::Custom { name } if name == "pallas_rms_norm"));
    }

    #[test]
    fn unsupported_opcode_errors_helpfully() {
        let text = "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT w = f32[2]{0} while(p0), condition=c, body=b\n}\n";
        let err = parse_hlo_text(text, "bad").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unsupported HLO opcode"));
        assert!(msg.contains("'w'"), "must name the offending instruction: {msg}");
    }

    #[test]
    fn unsupported_opcode_suggests_near_misses() {
        // a truncated / versioned opcode gets prefix-matched suggestions
        let text = "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT r = f32[2]{0} reduce-window(p0)\n}\n";
        let msg = format!("{:#}", parse_hlo_text(text, "bad").unwrap_err());
        assert!(msg.contains("did you mean"), "expected suggestions: {msg}");
        assert!(msg.contains("reduce"), "nearest opcode should be listed: {msg}");
        // something with no shared prefix gets no suggestion list
        let text2 = "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT q = f32[2]{0} zzz(p0)\n}\n";
        let msg2 = format!("{:#}", parse_hlo_text(text2, "bad").unwrap_err());
        assert!(!msg2.contains("did you mean"), "no suggestions expected: {msg2}");
    }

    /// Corrupted-input battery: every malformed module must come back as a
    /// parse error, never a panic (the CLI feeds this parser untrusted
    /// files).
    #[test]
    fn corrupted_modules_error_instead_of_panicking() {
        let cases: &[(&str, &str)] = &[
            (
                "missing binary operand",
                "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT a = f32[2]{0} add(p0)\n}\n",
            ),
            (
                "unary with no operands",
                "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT n = f32[2]{0} negate()\n}\n",
            ),
            (
                "broadcast with no operand",
                "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT b = f32[2,2]{1,0} broadcast(), dimensions={}\n}\n",
            ),
            (
                "slice with more ranges than rank",
                "HloModule m\n\nENTRY e {\n  p0 = f32[4,4]{1,0} parameter(0)\n  ROOT s = f32[2,2]{1,0} slice(p0), slice={[0:2], [0:2], [0:1]}\n}\n",
            ),
            (
                "slice with reversed bounds",
                "HloModule m\n\nENTRY e {\n  p0 = f32[4,4]{1,0} parameter(0)\n  ROOT s = f32[2,4]{1,0} slice(p0), slice={[3:1], [0:4]}\n}\n",
            ),
            (
                "reduce with out-of-range dim",
                "HloModule m\n\nENTRY e {\n  p0 = f32[4,4]{1,0} parameter(0)\n  ROOT r = f32[4]{0} reduce(p0), dimensions={5}\n}\n",
            ),
            (
                "reduce with negative dim",
                "HloModule m\n\nENTRY e {\n  p0 = f32[4,4]{1,0} parameter(0)\n  ROOT r = f32[4]{0} reduce(p0), dimensions={-1}\n}\n",
            ),
            (
                "unknown operand name",
                "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0)\n  ROOT a = f32[2]{0} add(p0, ghost)\n}\n",
            ),
            (
                "instruction with no equals sign",
                "HloModule m\n\nENTRY e {\n  what even is this line\n}\n",
            ),
            (
                "unbalanced parens",
                "HloModule m\n\nENTRY e {\n  p0 = f32[2]{0} parameter(0\n}\n",
            ),
            ("no entry computation", "HloModule m\n\nnothing here\n"),
            (
                "garbage shape dims",
                "HloModule m\n\nENTRY e {\n  p0 = f32[two,three]{1,0} parameter(0)\n}\n",
            ),
        ];
        for (what, text) in cases {
            let res = parse_hlo_text(text, what);
            assert!(
                res.is_err(),
                "{what}: expected a parse error, got {:?}",
                res.map(|g| g.num_nodes())
            );
        }
    }

    /// Repeated reduce dimensions must not underflow the removed-axis
    /// adjustment (they dedup to a single reduction).
    #[test]
    fn duplicate_reduce_dims_dedup() {
        let text = "HloModule m\n\nENTRY e {\n  p0 = f32[4,4]{1,0} parameter(0)\n  ROOT r = f32[4]{0} reduce(p0), dimensions={0,0}\n}\n";
        let g = parse_hlo_text(text, "dup").unwrap();
        assert_eq!(g.shape(g.outputs[0]), &[4]);
    }
}
