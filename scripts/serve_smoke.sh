#!/usr/bin/env bash
# Smoke-test `graphguard serve` end to end over stdin/stdout: a canned
# NDJSON request stream (two named workloads, an unparseable line, an
# unknown workload, a repeated workload) must produce one structured
# response per request line, byte-stable canonical output across server
# sessions, and warm shared-cache hits on the repeated request. Run by CI
# (fuzz-smoke job) and scripts/ci-local.sh after the release build exists.
set -euo pipefail
cd "$(dirname "$0")/.."

bin=(cargo run --release --bin graphguard --)
tmpdir="$(mktemp -d)"
trap 'rm -rf "$tmpdir"' EXIT

cat > "$tmpdir/requests.ndjson" <<'EOF'
{"id":"r1","workload":"gpt_tp_sp_2","ranks":2}
{"id":"r2","workload":"qwen2_tp_2","ranks":2}
this line is not json
{"id":"r3","workload":"no_such_model","ranks":2}
{"id":"r4","workload":"gpt_tp_sp_2","ranks":2}
EOF

echo "==> serve answers every request line (canonical, session A)"
"${bin[@]}" serve --canonical < "$tmpdir/requests.ndjson" > "$tmpdir/responses_a.ndjson"

echo "==> canonical responses are byte-stable across server sessions"
"${bin[@]}" serve --canonical < "$tmpdir/requests.ndjson" > "$tmpdir/responses_b.ndjson"
diff -u "$tmpdir/responses_a.ndjson" "$tmpdir/responses_b.ndjson"

echo "==> response stream checks (ids, verdicts, schema_version)"
python3 - "$tmpdir/responses_a.ndjson" <<'PY'
import json
import sys

rows = [json.loads(l) for l in open(sys.argv[1]) if l.strip()]
assert len(rows) == 5, f"expected 5 responses, got {len(rows)}"
got = [(r.get("id"), r["verdict"]) for r in rows]
want = [("r1", "verified"), ("r2", "verified"), (None, "error"),
        ("r3", "error"), ("r4", "verified")]
assert got == want, f"{got} != {want}"
for r in rows:
    assert isinstance(r.get("schema_version"), int) and r["schema_version"] >= 1, r
assert "no_such_model" in rows[3]["error"], rows[3]
for r in rows:
    if r["verdict"] == "verified":
        assert r.get("relation") is not None, f"verified response needs a relation: {r}"
        assert "wall_us" not in r, f"canonical response must drop wall_us: {r}"
print("ids, verdicts and schema_version all as expected")
PY

echo "==> shared cache warms across requests (r4 replays r1)"
"${bin[@]}" serve < "$tmpdir/requests.ndjson" > "$tmpdir/responses_warm.ndjson"
python3 - "$tmpdir/responses_warm.ndjson" <<'PY'
import json
import sys

rows = [json.loads(l) for l in open(sys.argv[1]) if l.strip()]
r4 = rows[4]
assert r4["verdict"] == "verified", r4
assert r4["cache_hits"] > 0, f"repeat request must hit the shared cache: {r4}"
print(f"r4 cache_hits={r4['cache_hits']} cache_misses={r4['cache_misses']}")
PY

echo
echo "serve_smoke: all serve gates passed"
