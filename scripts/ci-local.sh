#!/usr/bin/env bash
# CI parity: run the exact gate .github/workflows/ci.yml applies to a PR,
# in the same order, so any toolchain-bearing machine can reproduce a CI
# verdict with one command. Steps (both CI jobs, serialized):
#
#   rust job:        build → test (incl. chaos) → fmt → clippy (-D warnings)
#   fuzz-smoke job:  suite → parallel-determinism gate → serve smoke →
#                    lint gate → incremental-determinism gate →
#                    fuzz smoke → lint-triage gate → resume drill →
#                    fig4 + fuzz + cache + serve + patch benches →
#                    cache-effectiveness gate → bench gate
#
# Pass --quick to stop after the rust job (the fast pre-push check).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci-local: ERROR: 'cargo' not found on PATH — nothing was checked." >&2
    echo "ci-local: install a Rust toolchain (rust-toolchain.toml pins 1.79.0)" >&2
    echo "ci-local: e.g. via https://rustup.rs, then re-run this script." >&2
    exit 2
fi

step() {
    echo
    echo "==> $*"
    "$@"
}

step cargo build --release
step cargo test -q
step cargo test -q --features chaos --test chaos
step cargo fmt --check
step cargo clippy --all-targets -- -D warnings
step cargo clippy --all-targets --features chaos -- -D warnings

if [ "${1:-}" = "--quick" ]; then
    echo
    echo "ci-local: quick gate passed (suite/fuzz/bench skipped)"
    exit 0
fi

step cargo run --release --bin graphguard -- suite --ranks 2

# Parallel-walk determinism gate: the canonical suite report (no durations,
# no cache counters) must be byte-identical across jobs∈{1,4}, cached or
# not. Separate processes, so each run starts with a cold global cache.
echo
echo "==> parallel-walk determinism gate (suite --jobs 4 == --jobs 1)"
tmpdir="$(mktemp -d)"
trap 'rm -rf "$tmpdir"' EXIT
cargo run --release --bin graphguard -- suite --ranks 2 --jobs 1 --canonical \
    > "$tmpdir/suite_jobs1.txt"
cargo run --release --bin graphguard -- suite --ranks 2 --jobs 4 --canonical \
    > "$tmpdir/suite_jobs4.txt"
diff -u "$tmpdir/suite_jobs1.txt" "$tmpdir/suite_jobs4.txt"
cargo run --release --bin graphguard -- suite --ranks 2 --jobs 4 --no-cache --canonical \
    > "$tmpdir/suite_jobs4_nocache.txt"
diff -u "$tmpdir/suite_jobs1.txt" "$tmpdir/suite_jobs4_nocache.txt"
echo "canonical suite report is jobs- and cache-invariant"

step ./scripts/serve_smoke.sh

# ShardFlow lint gate: silent on every clean graph, loud (exit 1, JSON
# loci) on every *_killed wiring-bug fixture.
echo
echo "==> lint gate (clean graphs silent, wiring-bug fixtures flagged)"
cargo run --release --bin graphguard -- lint --ranks 2
cargo run --release --bin graphguard -- lint --ranks 4 --json > /dev/null
for f in rust/tests/fixtures/*_clean_verifies.json; do
    cargo run --release --bin graphguard -- lint --fixture "$f"
done
for f in rust/tests/fixtures/*_killed.json; do
    if cargo run --release --bin graphguard -- lint --json --fixture "$f" > "$tmpdir/lint_out.json"; then
        echo "lint gate: $f must be flagged" >&2
        exit 1
    fi
    grep -q '"node"' "$tmpdir/lint_out.json" \
        || { echo "lint gate: $f findings need loci" >&2; exit 1; }
done
echo "lint gate passed"

# Incremental-determinism gate: `reverify --canonical` (old pair + patch)
# must match `verify --canonical` of the patched pair (produced by
# `graphguard patch`) byte for byte on stdout AND in exit code, for both a
# clean and a refuting patch; a structurally invalid patch must exit 2.
echo
echo "==> incremental-determinism gate (reverify --canonical == verify --canonical)"
fix=rust/tests/fixtures/patch
cargo run --release --bin graphguard -- patch --gd "$fix/fig1_gd.json" \
    --patch "$fix/fig1_clean.patch.json" > "$tmpdir/gd_clean.json"
cargo run --release --bin graphguard -- patch --gd "$fix/fig1_gd.json" \
    --patch "$fix/fig1_bug.patch.json" > "$tmpdir/gd_bug.json"
for p in clean bug; do
    set +e
    cargo run --release --bin graphguard -- verify --canonical \
        --gs "$fix/fig1_gs.json" --gd "$tmpdir/gd_$p.json" \
        --ri "$fix/fig1_ri.json" > "$tmpdir/full_$p.txt" 2>/dev/null
    full_rc=$?
    cargo run --release --bin graphguard -- reverify --canonical \
        --gs "$fix/fig1_gs.json" --gd "$fix/fig1_gd.json" \
        --ri "$fix/fig1_ri.json" --patch "$fix/fig1_$p.patch.json" \
        > "$tmpdir/inc_$p.txt" 2>/dev/null
    inc_rc=$?
    set -e
    if [ "$full_rc" != "$inc_rc" ]; then
        echo "incremental gate: exit codes diverged on $p patch: full=$full_rc reverify=$inc_rc" >&2
        exit 1
    fi
    diff -u "$tmpdir/full_$p.txt" "$tmpdir/inc_$p.txt"
done
set +e
cargo run --release --bin graphguard -- reverify --canonical \
    --gs "$fix/fig1_gs.json" --gd "$fix/fig1_gd.json" \
    --ri "$fix/fig1_ri.json" --patch "$fix/fig1_invalid.patch.json" \
    > /dev/null 2>&1
invalid_rc=$?
set -e
if [ "$invalid_rc" != 2 ]; then
    echo "incremental gate: invalid patch must exit 2, got $invalid_rc" >&2
    exit 1
fi
echo "incremental re-verification is byte-identical to full verification"

step cargo run --release --bin graphguard -- fuzz --seeds 50 --seed 0

# triage counters ride in FUZZ_REPORT.json; a lint finding on a clean pair
# is a soundness violation (sound() already fails the fuzz step — this
# re-asserts it on the artifact)
echo
echo "==> lint triage gate (lint_false_alarms == 0)"
python3 - <<'EOF'
import json
r = json.load(open('FUZZ_REPORT.json'))
assert r['lint_false_alarms'] == 0, r
print('lint_false_alarms == 0; flagged', r['lint_flagged'],
      '/ silent-refuted', r['lint_silent_refuted'])
EOF

step ./scripts/resume_smoke.sh
step cargo bench --bench fig4_verification_time
step cargo bench --bench fuzz_throughput
step cargo bench --bench cache_effectiveness
step ./scripts/check_cache_effectiveness.sh BENCH_cache.json
step cargo bench --bench serve_latency
step cargo bench --bench patch_reverify
step ./scripts/bench_compare.sh BENCH_baseline .

echo
echo "ci-local: full CI gate passed"
