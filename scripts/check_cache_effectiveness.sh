#!/usr/bin/env bash
# Cache-effectiveness gate: assert the fingerprint-cache acceptance floor
# on a fresh BENCH_cache.json (written by `cargo bench --bench
# cache_effectiveness` — an L=8 repeated-layer GPT workload).
#
#   usage: scripts/check_cache_effectiveness.sh [BENCH_cache.json]
#
# Asserts, independently of wall time (that part is bench_compare.sh's
# job): the warm run's hit-rate meets the (L−1)/L floor, the cold run
# actually exercised the cache, and the no-cache control reported zero
# cache traffic. The bench binary asserts the same bounds before writing
# the file; this re-checks the committed artifact so a schema drift or a
# stale file can't silently pass the job.
set -euo pipefail

file="${1:-BENCH_cache.json}"
if [ ! -f "$file" ]; then
    echo "check_cache_effectiveness: '$file' not found — run" >&2
    echo "  cargo bench --bench cache_effectiveness" >&2
    exit 1
fi

python3 - "$file" <<'PY'
import json
import sys

L = 8  # layers in the bench workload (benches/cache_effectiveness.rs)
path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)
rows = {r["workload"]: r for r in doc.get("results", [])}

def row(name):
    if name not in rows:
        sys.exit(f"{path}: missing row '{name}' (bench schema drifted?)")
    return rows[name]

nocache = row("gpt8_nocache")
if nocache["cache_hits"] or nocache["cache_misses"]:
    sys.exit(f"{path}: gpt8_nocache control must report zero cache traffic, "
             f"got {nocache['cache_hits']}/{nocache['cache_misses']}")

cold = row("gpt8_cold")
if cold["cache_hits"] + cold["cache_misses"] == 0:
    sys.exit(f"{path}: gpt8_cold reports no cache traffic at all")

floor = (L - 1) / L
for name in ("gpt8_warm", "gpt8_warm_jobs4"):
    warm = row(name)
    total = warm["cache_hits"] + warm["cache_misses"]
    rate = warm["cache_hits"] / total if total else 0.0
    print(f"{name}: hit-rate {rate:.3f} ({warm['cache_hits']}/{total}, "
          f"floor {floor:.3f})")
    if rate < floor:
        sys.exit(f"{path}: {name} hit-rate {rate:.3f} below the "
                 f"(L-1)/L acceptance floor {floor:.3f}")

print("cache effectiveness gate passed")
PY
