#!/usr/bin/env bash
# Promote freshly measured BENCH_<name>.json files into BENCH_baseline/,
# replacing the bootstrap placeholders and arming the regression gate.
#
#   usage: scripts/populate_baselines.sh [FRESH_DIR] [BASELINE_DIR]
#
# FRESH_DIR (default .) should hold BENCH_*.json files written by the
# cargo bench targets — either locally or extracted from the CI
# `fuzz-and-bench` artifact (the trusted source; see
# BENCH_baseline/README.md). Only benches that already have a slot in
# BASELINE_DIR are promoted, so a new bench must first commit a bootstrap
# placeholder — this keeps the set of gated benches an explicit, reviewed
# decision. A fresh file that itself carries `"bootstrap": true` or has no
# timed rows is refused: the gate must never be armed with fabricated or
# empty timings.
set -euo pipefail

fresh_dir="${1:-.}"
baseline_dir="${2:-BENCH_baseline}"

if [ ! -d "$baseline_dir" ]; then
    echo "populate_baselines: baseline directory '$baseline_dir' not found" >&2
    exit 1
fi

shopt -s nullglob
slots=("$baseline_dir"/BENCH_*.json)
if [ ${#slots[@]} -eq 0 ]; then
    echo "populate_baselines: no baseline slots under '$baseline_dir'" >&2
    exit 1
fi

promoted=0
for slot in "${slots[@]}"; do
    name="$(basename "$slot")"
    fresh="$fresh_dir/$name"
    if [ ! -f "$fresh" ]; then
        echo "  SKIP $name: no fresh measurement in '$fresh_dir'"
        continue
    fi
    python3 - "$fresh" <<'PY'
import json
import sys

path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)
if doc.get("bootstrap"):
    sys.exit(f"{path}: refusing to promote a bootstrap placeholder as a baseline")
rows = doc.get("results", [])
if not rows or sum(r.get("wall_ns", 0) for r in rows) <= 0:
    sys.exit(f"{path}: refusing to promote a baseline with no timed rows")
PY
    cp "$fresh" "$slot"
    echo "  PROMOTED $name"
    promoted=$((promoted + 1))
done

if [ "$promoted" -eq 0 ]; then
    echo "populate_baselines: nothing promoted (run the cargo bench targets first)" >&2
    exit 1
fi
echo "populate_baselines: $promoted baseline(s) updated — review and commit $baseline_dir/"
