#!/usr/bin/env bash
# Bench regression gate: compare fresh BENCH_<name>.json files against the
# committed snapshots under BENCH_baseline/ and fail on a wall-time
# regression beyond the threshold (default 25%).
#
#   usage: scripts/bench_compare.sh [BASELINE_DIR] [FRESH_DIR]
#
# Every BENCH_*.json in BASELINE_DIR is compared with the file of the same
# name in FRESH_DIR by *summed* wall_ns across its result rows (the schema
# documented in EXPERIMENTS.md). Baselines marked `"bootstrap": true` are
# skipped with a notice: they are placeholders awaiting population from a
# trusted CI run (see BENCH_baseline/README.md). A baseline whose fresh
# counterpart is missing fails the gate — the bench did not run.
#
# Environment:
#   BENCH_REGRESSION_THRESHOLD  fractional slowdown allowed (default 0.25)
set -euo pipefail

baseline_dir="${1:-BENCH_baseline}"
fresh_dir="${2:-.}"
threshold="${BENCH_REGRESSION_THRESHOLD:-0.25}"

if [ ! -d "$baseline_dir" ]; then
    echo "bench_compare: baseline directory '$baseline_dir' not found" >&2
    exit 1
fi

shopt -s nullglob
baselines=("$baseline_dir"/BENCH_*.json)
if [ ${#baselines[@]} -eq 0 ]; then
    echo "bench_compare: no BENCH_*.json baselines under '$baseline_dir'" >&2
    exit 1
fi

python3 - "$threshold" "$fresh_dir" "${baselines[@]}" <<'PY'
import json
import os
import sys

threshold = float(sys.argv[1])
fresh_dir = sys.argv[2]
failures = []

print(f"{'bench':<12} {'baseline':>14} {'fresh':>14} {'ratio':>8}  verdict")
for path in sys.argv[3:]:
    name = os.path.basename(path)
    with open(path) as f:
        base = json.load(f)
    if base.get("bootstrap"):
        print(f"{name:<12} {'—':>14} {'—':>14} {'—':>8}  SKIP (bootstrap baseline, "
              f"populate from a CI artifact)")
        continue
    base_total = sum(r["wall_ns"] for r in base.get("results", []))
    if base_total <= 0:
        failures.append(f"{name}: baseline has no timed results")
        continue
    fresh_path = os.path.join(fresh_dir, name)
    if not os.path.exists(fresh_path):
        failures.append(f"{name}: fresh result missing (bench did not run?)")
        continue
    with open(fresh_path) as f:
        fresh = json.load(f)
    fresh_total = sum(r["wall_ns"] for r in fresh.get("results", []))
    if fresh_total <= 0:
        failures.append(f"{name}: fresh result has no timed rows "
                        f"(bench crashed or schema drifted?)")
        continue
    ratio = fresh_total / base_total
    verdict = "ok" if ratio <= 1.0 + threshold else f"REGRESSION (> {threshold:.0%})"
    print(f"{name:<12} {base_total:>14} {fresh_total:>14} {ratio:>8.3f}  {verdict}")
    if ratio > 1.0 + threshold:
        failures.append(f"{name}: wall time {ratio:.3f}x baseline "
                        f"(allowed {1.0 + threshold:.2f}x)")

if failures:
    print("\nbench regression gate FAILED:", file=sys.stderr)
    for f in failures:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print("\nbench regression gate passed")
PY
