#!/usr/bin/env bash
# Crash-drill gate: a fuzz campaign killed mid-run and resumed from its
# journal must finish with a FUZZ_REPORT.json byte-identical to an
# uninterrupted run of the same campaign.
#
#   1. reference run: all seeds in one go            -> FUZZ_REPORT.json (A)
#   2. drill run:     --abort-after N stops early    -> exit code 4, journal
#   3. resume:        --resume DIR replays + finishes -> FUZZ_REPORT.json (B)
#   4. diff A B — any byte of drift fails the gate
#
# Usage: resume_smoke.sh [SEEDS] [ABORT_AFTER] [BASE_SEED]
set -euo pipefail
cd "$(dirname "$0")/.."

SEEDS="${1:-30}"
ABORT_AFTER="${2:-11}"
BASE_SEED="${3:-0}"
BIN="cargo run --release --quiet --bin graphguard --"

work="$(mktemp -d "${TMPDIR:-/tmp}/gg_resume_smoke.XXXXXX")"
trap 'rm -rf "$work"' EXIT

echo "==> resume smoke: reference run ($SEEDS seeds)"
$BIN fuzz --seeds "$SEEDS" --seed "$BASE_SEED" --out "$work/full"
mv FUZZ_REPORT.json "$work/report_full.json"

echo "==> resume smoke: crash drill (abort after $ABORT_AFTER fresh seeds)"
rc=0
$BIN fuzz --seeds "$SEEDS" --seed "$BASE_SEED" --out "$work/drill" \
    --abort-after "$ABORT_AFTER" || rc=$?
if [ "$rc" -ne 4 ]; then
    echo "resume_smoke: ERROR: expected exit code 4 from --abort-after, got $rc" >&2
    exit 1
fi
if [ ! -f "$work/drill/journal.jsonl" ]; then
    echo "resume_smoke: ERROR: aborted campaign left no journal" >&2
    exit 1
fi
if [ -f FUZZ_REPORT.json ]; then
    echo "resume_smoke: ERROR: aborted campaign must not write FUZZ_REPORT.json" >&2
    exit 1
fi

echo "==> resume smoke: resuming from $work/drill"
$BIN fuzz --resume "$work/drill"
mv FUZZ_REPORT.json "$work/report_resumed.json"

if ! diff -u "$work/report_full.json" "$work/report_resumed.json"; then
    echo "resume_smoke: ERROR: resumed report differs from uninterrupted run" >&2
    exit 1
fi
echo "resume_smoke: OK — resumed report is byte-identical ($SEEDS seeds, drill at $ABORT_AFTER)"
