"""L2 model semantics + graph capture.

The ground truth the whole pipeline rests on: the TP=2 distributed Llama
block computes the same function as the sequential one, gradient
accumulation (correctly rescaled) matches full-batch loss, and the jaxpr
capture emits structurally valid GraphGuard JSON for all of them.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.capture import capture


def test_llama_tp2_matches_seq():
    seq_args = model.llama_example_args()
    tp_args = model.split_for_tp2(seq_args)
    (out_seq,) = model.llama_block_seq(*seq_args)
    (out_tp,) = model.llama_block_tp2(*tp_args)
    np.testing.assert_allclose(out_seq, out_tp, rtol=1e-4, atol=1e-5)


def test_grad_accum_scaled_matches_full_batch():
    x, y, w, b = model.regression_example_args()
    (full,) = model.regression_seq(x, y, w, b)
    (acc,) = model.regression_grad_accum(x[:4], x[4:], y[:4], y[4:], w, b, scaled=True)
    np.testing.assert_allclose(full, acc, rtol=1e-5, atol=1e-6)
    # the BUGGY variant is 2x off — the bug-6 signal
    (buggy,) = model.regression_grad_accum(x[:4], x[4:], y[:4], y[4:], w, b, scaled=False)
    np.testing.assert_allclose(buggy, 2.0 * full, rtol=1e-5, atol=1e-6)


def test_grad_accum_gradients_match():
    x, y, w, b = model.regression_example_args()
    g_full = jax.grad(lambda w, b: model.regression_seq(x, y, w, b)[0], argnums=(0, 1))(w, b)
    g_acc = jax.grad(
        lambda w, b: model.regression_grad_accum(x[:4], x[4:], y[:4], y[4:], w, b)[0],
        argnums=(0, 1),
    )(w, b)
    for a, bb in zip(g_full, g_acc):
        np.testing.assert_allclose(a, bb, rtol=1e-5, atol=1e-6)


def _check_graph_schema(g):
    names = {i["name"] for i in g["inputs"]}
    for node in g["nodes"]:
        for inp in node["inputs"]:
            assert inp in names, f"node {node['name']} references unknown {inp}"
        names.add(node["name"])
    for out in g["outputs"]:
        assert out in names


def test_capture_llama_seq():
    args = model.llama_example_args()
    g = capture(model.llama_block_seq, args, "llama_seq")
    _check_graph_schema(g)
    ops = [n["op"] for n in g["nodes"]]
    assert ops.count("pallas_rms_norm") == 2, "both norms captured as the Pallas custom op"
    assert ops.count("pallas_attention") == model.HEADS
    assert "matmul" in ops and "concat" in ops
    # round-trips through JSON text
    g2 = json.loads(json.dumps(g))
    assert g2 == g


def test_capture_llama_tp2():
    args = model.split_for_tp2(model.llama_example_args())
    g = capture(model.llama_block_tp2, args, "llama_tp2")
    _check_graph_schema(g)
    assert len(g["inputs"]) == 19
    ops = [n["op"] for n in g["nodes"]]
    assert ops.count("pallas_attention") == model.HEADS  # heads split across ranks


def test_capture_regression_pair():
    x, y, w, b = model.regression_example_args()
    gs = capture(model.regression_seq, (x, y, w, b), "regression_seq")
    gd = capture(
        model.regression_grad_accum, (x[:4], x[4:], y[:4], y[4:], w, b), "regression_ga2"
    )
    _check_graph_schema(gs)
    _check_graph_schema(gd)
    assert any(n["op"] == "mse_loss" or n["op"] == "reduce_sum" for n in gs["nodes"])


def test_capture_rejects_unknown_primitives():
    import pytest

    def weird(x):
        return (jnp.cumsum(x),)

    with pytest.raises(NotImplementedError):
        capture(weird, (jnp.ones((4,), jnp.float32),), "weird")
