"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes-adjacent parameters; assert_allclose
against ref.py is THE correctness signal for the kernels the verified
models call.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref, rms_norm_ref, rope_ref
from compile.kernels.rmsnorm import rms_norm, vmem_footprint_bytes


def randn(rng, *shape, scale=0.5):
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 4, 8, 16]),
    hidden=st.sampled_from([4, 8, 16, 64]),
    block=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_rms_norm_matches_ref(rows, hidden, block, seed):
    rng = np.random.default_rng(seed)
    x = randn(rng, rows, hidden)
    w = randn(rng, hidden, scale=1.0)
    got = rms_norm(x, w, block_rows=block)
    want = rms_norm_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    seq=st.sampled_from([2, 4, 8, 16]),
    dim=st.sampled_from([2, 4, 8]),
    block=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(seq, dim, block, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (randn(rng, seq, dim) for _ in range(3))
    got = attention(q, k, v, block_q=block)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_rms_norm_large_values_stable():
    x = jnp.full((4, 8), 1e4, dtype=jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    out = rms_norm(x, w)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, rms_norm_ref(x, w), rtol=1e-5)


def test_attention_rows_are_convex_combinations():
    rng = np.random.default_rng(3)
    q, k = (randn(rng, 8, 4) for _ in range(2))
    v = jnp.asarray(rng.uniform(0.0, 1.0, size=(8, 4)), jnp.float32)
    out = attention(q, k, v)
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-5
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-5


def test_rope_preserves_pair_norms():
    rng = np.random.default_rng(4)
    x = randn(rng, 8, 4)
    theta = jnp.asarray(rng.uniform(0, 2 * np.pi, size=(8, 2)), jnp.float32)
    cos = jnp.concatenate([jnp.cos(theta)] * 2, axis=1)
    sin = jnp.concatenate([jnp.sin(theta)] * 2, axis=1)
    out = rope_ref(x, cos, sin)
    # rotation preserves the norm of each (x1_i, x2_i) pair
    def pair_norms(t):
        a, b = t[:, :2], t[:, 2:]
        return a * a + b * b

    np.testing.assert_allclose(pair_norms(out), pair_norms(x), rtol=1e-4, atol=1e-5)


def test_kernels_jit_compile():
    rng = np.random.default_rng(5)
    x, w = randn(rng, 8, 16), randn(rng, 16)
    jitted = jax.jit(lambda x, w: rms_norm(x, w))
    np.testing.assert_allclose(jitted(x, w), rms_norm_ref(x, w), rtol=1e-5, atol=1e-6)


def test_vmem_footprint_under_budget():
    # DESIGN.md §Perf: default tile fits VMEM with huge headroom
    assert vmem_footprint_bytes(8, 4096) < 16 * 2**20
    # and the largest tile we would ever pick still fits
    assert vmem_footprint_bytes(240, 4096) < 16 * 2**20
