"""L2 JAX models (build-time only; never on the request path).

A Llama-style block built on the L1 Pallas kernels, in a sequential variant
`G_s` and a rank-simulated tensor-parallel variant `G_d` (per-rank weight
shards as separate arguments, collectives as their single-program semantic
equivalents — exactly the form the paper's single-process graph capture
sees), plus the HF-style regression pair for gradient accumulation.

These are the *captured* workloads: `capture.py` walks their jaxprs into
the GraphGuard graph JSON, and `aot.py` lowers them to HLO text for the
Rust PJRT runtime. Model structure deliberately mirrors
`rust/src/models/llama.rs` / `regression.rs` so the two capture paths
cross-check each other.
"""

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.ref import rope_ref
from .kernels.rmsnorm import rms_norm

SEQ = 8
HEADS = 4
HEAD_DIM = 4
HIDDEN = HEADS * HEAD_DIM
FFN = 32


def _heads(q, k, v, cos, sin, heads, head_dim):
    outs = []
    for i in range(heads):
        lo, hi = i * head_dim, (i + 1) * head_dim
        qi = rope_ref(q[:, lo:hi], cos, sin)
        ki = rope_ref(k[:, lo:hi], cos, sin)
        outs.append(attention(qi, ki, v[:, lo:hi]))
    return jnp.concatenate(outs, axis=1)


def llama_block_seq(x, cos, sin, w_rms1, wq, wk, wv, wo, w_rms2, wg, wu, wd):
    """Sequential Llama block (G_s): Pallas RMSNorm + per-head RoPE
    attention (Pallas kernel) + SwiGLU MLP with an explicit sigmoid-based
    silu (kept as primitive ops the capture layer understands)."""
    n1 = rms_norm(x, w_rms1)
    q, k, v = n1 @ wq, n1 @ wk, n1 @ wv
    attn = _heads(q, k, v, cos, sin, HEADS, HEAD_DIM)
    x1 = x + attn @ wo
    n2 = rms_norm(x1, w_rms2)
    gate = n2 @ wg
    act = gate * jax.nn.sigmoid(gate) * (n2 @ wu)
    return (x1 + act @ wd,)


def llama_block_tp2(
    x, cos, sin, w_rms1, wq0, wq1, wk0, wk1, wv0, wv1, wo0, wo1, w_rms2, wg0, wg1, wu0, wu1, wd0, wd1
):
    """Rank-simulated TP=2 Llama block: G_d.

    Column-parallel QKV/gate/up (per-rank halves as separate args),
    row-parallel projections whose partial products are summed — the
    single-program form of the all-reduce.
    """
    heads_per = HEADS // 2
    n1 = rms_norm(x, w_rms1)
    parts = []
    for wq_r, wk_r, wv_r, wo_r in ((wq0, wk0, wv0, wo0), (wq1, wk1, wv1, wo1)):
        q, k, v = n1 @ wq_r, n1 @ wk_r, n1 @ wv_r
        attn = _heads(q, k, v, cos, sin, heads_per, HEAD_DIM)
        parts.append(attn @ wo_r)
    proj = parts[0] + parts[1]  # all-reduce
    x1 = x + proj
    n2 = rms_norm(x1, w_rms2)
    mlp_parts = []
    for wg_r, wu_r, wd_r in ((wg0, wu0, wd0), (wg1, wu1, wd1)):
        gate = n2 @ wg_r
        act = gate * jax.nn.sigmoid(gate) * (n2 @ wu_r)
        mlp_parts.append(act @ wd_r)
    mlp = mlp_parts[0] + mlp_parts[1]  # all-reduce
    return (x1 + mlp,)


def llama_example_args():
    import numpy as np

    rng = np.random.default_rng(0)
    f = lambda *s: jnp.asarray(rng.normal(size=s, scale=0.5), dtype=jnp.float32)
    x = f(SEQ, HIDDEN)
    cos = jnp.asarray(np.cos(np.arange(SEQ * HEAD_DIM).reshape(SEQ, HEAD_DIM) * 0.1), jnp.float32)
    sin = jnp.asarray(np.sin(np.arange(SEQ * HEAD_DIM).reshape(SEQ, HEAD_DIM) * 0.1), jnp.float32)
    seq_args = (
        x,
        cos,
        sin,
        f(HIDDEN),
        f(HIDDEN, HIDDEN),
        f(HIDDEN, HIDDEN),
        f(HIDDEN, HIDDEN),
        f(HIDDEN, HIDDEN),
        f(HIDDEN),
        f(HIDDEN, FFN),
        f(HIDDEN, FFN),
        f(FFN, HIDDEN),
    )
    return seq_args


def split_for_tp2(seq_args):
    """Shard the sequential arguments the way the TP=2 variant expects."""
    (x, cos, sin, w_rms1, wq, wk, wv, wo, w_rms2, wg, wu, wd) = seq_args
    h2 = HIDDEN // 2
    f2 = FFN // 2
    return (
        x,
        cos,
        sin,
        w_rms1,
        wq[:, :h2],
        wq[:, h2:],
        wk[:, :h2],
        wk[:, h2:],
        wv[:, :h2],
        wv[:, h2:],
        wo[:h2, :],
        wo[h2:, :],
        w_rms2,
        wg[:, :f2],
        wg[:, f2:],
        wu[:, :f2],
        wu[:, f2:],
        wd[:f2, :],
        wd[f2:, :],
    )


# ---- HF-style regression with gradient accumulation (bug 6 workload) ----

BATCH = 8
IN_DIM = 4
OUT_DIM = 2


def regression_seq(x, y, w, b):
    pred = x @ w + b
    diff = pred - y
    loss = jnp.mean(diff * diff)
    return (loss,)


def regression_grad_accum(x0, x1, y0, y1, w, b, *, scaled=True):
    losses = []
    for xi, yi in ((x0, y0), (x1, y1)):
        pred = xi @ w + b
        diff = pred - yi
        li = jnp.mean(diff * diff)
        losses.append(li * 0.5 if scaled else li)
    return (losses[0] + losses[1],)


def regression_example_args():
    import numpy as np

    rng = np.random.default_rng(1)
    f = lambda *s: jnp.asarray(rng.normal(size=s, scale=0.5), dtype=jnp.float32)
    return f(BATCH, IN_DIM), f(BATCH, OUT_DIM), f(IN_DIM, OUT_DIM), f(OUT_DIM)
