"""AOT artifact builder: lower the L2 models to HLO *text* + capture their
graphs to GraphGuard JSON.

Run once by `make artifacts`; the Rust binary is self-contained afterwards.
HLO text (not `.serialize()`d protos) is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Outputs under artifacts/:
  llama_seq.hlo.txt, llama_tp2.hlo.txt        PJRT-executable modules
  regression_seq.hlo.txt, regression_ga2.hlo.txt
  graphs/llama_{seq,tp2}.json                 captured graphs
  graphs/regression_{seq,ga2}.json
  graphs/llama_ri.json, graphs/regression_ri.json   clean input relations
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .capture import capture


def to_hlo_text(fn, args):
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def llama_ri():
    """Clean input relation for the TP=2 Llama pair, in G_d tensor names."""
    ri = {
        "x": ["x"],
        "cos": ["cos"],
        "sin": ["sin"],
        "w_rms1": ["w_rms1"],
        "w_rms2": ["w_rms2"],
    }
    for w, dim in (("wq", 1), ("wk", 1), ("wv", 1), ("wg", 1), ("wu", 1), ("wo", 0), ("wd", 0)):
        ri[w] = [f"concat({w}0, {w}1; dim={dim})"]
    return ri


def regression_ri():
    return {
        "x": ["concat(x0, x1; dim=0)"],
        "y": ["concat(y0, y1; dim=0)"],
        "w": ["w"],
        "b": ["b"],
    }


def build(outdir):
    os.makedirs(os.path.join(outdir, "graphs"), exist_ok=True)

    seq_args = model.llama_example_args()
    tp_args = model.split_for_tp2(seq_args)
    reg_args = model.regression_example_args()
    x, y, w, b = reg_args
    ga_args = (x[:4], x[4:], y[:4], y[4:], w, b)

    jobs = [
        ("llama_seq", model.llama_block_seq, seq_args),
        ("llama_tp2", model.llama_block_tp2, tp_args),
        ("regression_seq", model.regression_seq, reg_args),
        ("regression_ga2", model.regression_grad_accum, ga_args),
    ]
    for name, fn, args in jobs:
        hlo = to_hlo_text(fn, args)
        with open(os.path.join(outdir, f"{name}.hlo.txt"), "w") as f:
            f.write(hlo)
        graph = capture(fn, args, name)
        with open(os.path.join(outdir, "graphs", f"{name}.json"), "w") as f:
            json.dump(graph, f, indent=1)
        print(f"  {name}: {len(hlo)} chars HLO, {len(graph['nodes'])} captured nodes")

    with open(os.path.join(outdir, "graphs", "llama_ri.json"), "w") as f:
        json.dump(llama_ri(), f, indent=1)
    with open(os.path.join(outdir, "graphs", "regression_ri.json"), "w") as f:
        json.dump(regression_ri(), f, indent=1)

    # example input bundles for cross-validation (flat f32 lists)
    import numpy as np

    def dump_inputs(name, args):
        payload = [
            {"shape": list(np.asarray(a).shape), "data": np.asarray(a).ravel().tolist()}
            for a in args
        ]
        with open(os.path.join(outdir, "graphs", f"{name}_inputs.json"), "w") as f:
            json.dump(payload, f)

    dump_inputs("llama_seq", seq_args)
    dump_inputs("llama_tp2", tp_args)
    dump_inputs("regression_seq", reg_args)
    dump_inputs("regression_ga2", ga_args)
    print(f"artifacts written to {outdir}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    a = p.parse_args()
    build(a.out)
