"""Computation-graph capture: jaxpr → GraphGuard graph JSON (paper §5.1).

The analog of the paper's TorchDynamo capture (and of their 377-line
XLA→intermediate-format utility). `capture(fn, args, name)` traces the
function, walks the jaxpr, and emits the JSON schema `rust/src/ir/json_io.rs`
parses: inputs with shapes/dtypes, one node per supported primitive, named
outputs.

Pallas kernels appear as `pallas_call` equations; they are identified by
their argument signature ((x[s,h], w[h]) → pallas_rms_norm;
(q,k,v of one shape) → pallas_attention) — the same practical naming
workaround as the paper's `log_tensor` CustomOp.
"""

import json

import jax
import numpy as np

_UNARY = {
    "neg": "neg",
    "exp": "exp",
    "log": "log",
    "sqrt": "sqrt",
    "rsqrt": "rsqrt",
    "tanh": "tanh",
    "logistic": "sigmoid",
}
_BINARY = {"add": "add", "sub": "sub", "mul": "mul", "div": "div", "max": "maximum"}


class _Capture:
    def __init__(self, name):
        self.name = name
        self.inputs = []
        self.nodes = []
        self.names = {}  # jaxpr var -> tensor name
        self.consts = {}  # jaxpr var -> python scalar
        self.counter = 0

    def fresh(self, base):
        self.counter += 1
        return f"{base}_{self.counter}"

    def node(self, op, inputs, out_var, attrs=None, base=None):
        name = self.fresh(base or op)
        entry = {"op": op, "name": name, "inputs": inputs}
        if attrs:
            entry["attrs"] = attrs
        self.nodes.append(entry)
        self.names[out_var] = name
        return name

    def ref(self, atom):
        """Name for a jaxpr atom (variable or literal)."""
        try:
            from jax.extend.core import Literal
        except ImportError:  # older jax
            from jax.core import Literal

        if isinstance(atom, Literal):
            v = np.asarray(atom.val)
            if v.ndim == 0:
                return ("scalar", float(v))
            raise NotImplementedError(f"non-scalar literal {v.shape}")
        if atom in self.consts:
            return ("scalar", self.consts[atom])
        return ("tensor", self.names[atom])


def _dims_attr(x):
    return [int(d) for d in x]


def capture(fn, args, name):
    """Trace ``fn(*args)`` and return the graph as a JSON-able dict."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    cap = _Capture(name)

    import inspect

    try:
        argnames = list(inspect.signature(fn).parameters)
    except (TypeError, ValueError):
        argnames = []
    for i, var in enumerate(jaxpr.jaxpr.invars):
        aval = var.aval
        tname = argnames[i] if i < len(argnames) else f"arg{i}"
        dtype = "i64" if np.issubdtype(aval.dtype, np.integer) else "f32"
        cap.inputs.append(
            {"name": tname, "shape": [int(d) for d in aval.shape], "dtype": dtype}
        )
        cap.names[var] = tname
    for var, val in zip(jaxpr.jaxpr.constvars, jaxpr.consts):
        v = np.asarray(val)
        if v.ndim == 0:
            cap.consts[var] = float(v)
        else:
            cname = cap.fresh("const")
            cap.inputs.append(
                {"name": cname, "shape": list(v.shape), "dtype": "f32", "value": v.tolist()}
            )
            cap.names[var] = cname

    for eqn in jaxpr.jaxpr.eqns:
        _lower_eqn(cap, eqn)

    outputs = []
    for var in jaxpr.jaxpr.outvars:
        kind, ref = cap.ref(var)
        if kind != "tensor":
            raise NotImplementedError("scalar literal output")
        outputs.append(ref)
    return {"name": name, "inputs": cap.inputs, "nodes": cap.nodes, "outputs": outputs}


def _lower_eqn(cap, eqn):
    prim = eqn.primitive.name
    out = eqn.outvars[0]

    def tensor_in(i):
        kind, ref = cap.ref(eqn.invars[i])
        if kind != "tensor":
            raise NotImplementedError(f"{prim}: scalar where tensor expected")
        return ref

    if prim in _UNARY:
        cap.node(_UNARY[prim], [tensor_in(0)], out)
    elif prim in _BINARY:
        refs = [cap.ref(v) for v in eqn.invars]
        kinds = [k for k, _ in refs]
        if "scalar" in kinds:
            # fold scalar operand into scale/add_scalar
            (scalar_idx, tensor_idx) = (0, 1) if kinds[0] == "scalar" else (1, 0)
            c = refs[scalar_idx][1]
            t = refs[tensor_idx][1]
            if prim == "mul":
                cap.node("scale", [t], out, {"c": c})
            elif prim == "add":
                cap.node("add_scalar", [t], out, {"c": c})
            elif prim == "sub" and scalar_idx == 1:
                cap.node("add_scalar", [t], out, {"c": -c})
            elif prim == "div" and scalar_idx == 1:
                cap.node("scale", [t], out, {"c": 1.0 / c})
            else:
                raise NotImplementedError(f"{prim} with scalar on side {scalar_idx}")
        else:
            cap.node(_BINARY[prim], [refs[0][1], refs[1][1]], out)
    elif prim == "dot_general":
        ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
        la = eqn.invars[0].aval
        if list(lb) or list(rb):
            raise NotImplementedError("batched dot_general in capture")
        if list(lc) == [len(la.shape) - 1] and list(rc) == [0]:
            cap.node("matmul", [tensor_in(0), tensor_in(1)], out)
        else:
            raise NotImplementedError(f"dot_general dims {eqn.params['dimension_numbers']}")
    elif prim == "transpose":
        cap.node(
            "transpose", [tensor_in(0)], out, {"perm": _dims_attr(eqn.params["permutation"])}
        )
    elif prim == "reshape":
        cap.node(
            "reshape",
            [tensor_in(0)],
            out,
            {"shape": [int(d) for d in eqn.outvars[0].aval.shape]},
        )
    elif prim == "concatenate":
        cap.node(
            "concat",
            [tensor_in(i) for i in range(len(eqn.invars))],
            out,
            {"dim": int(eqn.params["dimension"])},
        )
    elif prim == "slice":
        starts = eqn.params["start_indices"]
        limits = eqn.params["limit_indices"]
        strides = eqn.params.get("strides") or [1] * len(starts)
        if any(s != 1 for s in strides):
            raise NotImplementedError("strided slice")
        src_shape = eqn.invars[0].aval.shape
        cur = tensor_in(0)
        for d, (a, b) in enumerate(zip(starts, limits)):
            if (a, b) != (0, src_shape[d]):
                nxt = cap.fresh("slice")
                cap.nodes.append(
                    {
                        "op": "slice",
                        "name": nxt,
                        "inputs": [cur],
                        "attrs": {"dim": d, "start": int(a), "end": int(b)},
                    }
                )
                cur = nxt
        cap.node("identity", [cur], out)
    elif prim == "reduce_sum":
        axes = sorted(eqn.params["axes"])
        cur = tensor_in(0)
        for removed, d in enumerate(axes):
            nxt = cap.fresh("rsum")
            cap.nodes.append(
                {
                    "op": "reduce_sum",
                    "name": nxt,
                    "inputs": [cur],
                    "attrs": {"dim": d - removed, "keepdim": False},
                }
            )
            cur = nxt
        cap.node("identity", [cur], out)
    elif prim == "reduce_max":
        axes = sorted(eqn.params["axes"])
        cur = tensor_in(0)
        for removed, d in enumerate(axes):
            nxt = cap.fresh("rmax")
            cap.nodes.append(
                {
                    "op": "reduce_max",
                    "name": nxt,
                    "inputs": [cur],
                    "attrs": {"dim": d - removed, "keepdim": False},
                }
            )
            cur = nxt
        cap.node("identity", [cur], out)
    elif prim == "broadcast_in_dim":
        # keepdim-style broadcasts are representational; our binary ops
        # broadcast natively, so pass the operand through (reshape when the
        # rank changed in a way identity can't express).
        kind, ref = cap.ref(eqn.invars[0])
        if kind == "scalar":
            cap.consts[out] = ref
            return
        in_shape = list(eqn.invars[0].aval.shape)
        out_shape = [int(d) for d in eqn.outvars[0].aval.shape]
        if int(np.prod(in_shape)) == int(np.prod(out_shape)):
            cap.node("reshape", [ref], out, {"shape": out_shape})
        else:
            raise NotImplementedError(
                f"materializing broadcast {in_shape} -> {out_shape}"
            )
    elif prim == "convert_element_type":
        kind, ref = cap.ref(eqn.invars[0])
        if kind == "scalar":
            cap.consts[out] = ref
        else:
            cap.node("identity", [ref], out)
    elif prim == "squeeze":
        out_shape = [int(d) for d in eqn.outvars[0].aval.shape]
        cap.node("reshape", [tensor_in(0)], out, {"shape": out_shape})
    elif prim == "pallas_call":
        in_shapes = [tuple(v.aval.shape) for v in eqn.invars]
        if len(in_shapes) == 2 and len(in_shapes[1]) == 1:
            cap.node(
                "pallas_rms_norm", [tensor_in(0), tensor_in(1)], out, base="pallas_rms"
            )
        elif len(in_shapes) == 3 and len({s for s in in_shapes}) == 1:
            cap.node(
                "pallas_attention",
                [tensor_in(0), tensor_in(1), tensor_in(2)],
                out,
                base="pallas_attn",
            )
        else:
            raise NotImplementedError(f"unrecognized pallas_call signature {in_shapes}")
    elif prim == "integer_pow":
        p = int(eqn.params["y"])
        if p == 2:
            cap.node("square", [tensor_in(0)], out)
        else:
            raise NotImplementedError(f"integer_pow {p}")
    elif prim == "stop_gradient" or prim == "copy":
        cap.node("identity", [tensor_in(0)], out)
    else:
        raise NotImplementedError(
            f"primitive '{prim}' not supported by capture — define a CustomOp "
            f"mapping (§5.1 best practices)"
        )


def capture_to_file(fn, args, name, path):
    graph = capture(fn, args, name)
    with open(path, "w") as f:
        json.dump(graph, f, indent=1)
    return graph
