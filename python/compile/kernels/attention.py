"""L1 Pallas kernel: row-blocked attention core softmax(q·kᵀ/√d)·v.

The grid walks query-row blocks; each step holds a (block_q, d) query tile
plus the full K/V for the (short) sequence in VMEM and fuses score
computation, the numerically-stable softmax, and the value matmul. This is
the flash-attention insight re-expressed for the TPU memory hierarchy:
BlockSpec plays the role of the CUDA threadblock tiling (no online-softmax
running rescale is needed while K/V fit in VMEM; see DESIGN.md
§Hardware-Adaptation for the scaling discussion).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T) * (1.0 / (d**0.5))
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.matmul(p, v)


def attention(q, k, v, *, block_q=8, interpret=True):
    """softmax(q·kᵀ/√d)·v for ``q,k,v: [s, d]`` (one head)."""
    s, d = q.shape
    if s % block_q != 0:
        block_q = s
    grid = (s // block_q,)
    return pl.pallas_call(
        _attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
