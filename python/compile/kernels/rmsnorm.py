"""L1 Pallas kernel: fused RMSNorm.

Rows are tiled into VMEM-sized blocks via BlockSpec — (block_rows, hidden)
per grid step — with the mean-square reduction and the rescale fused in one
pass over the tile (one HBM read, one HBM write per element; the GPU
formulation would assign a threadblock per row group, the TPU formulation
expresses the same schedule with the BlockSpec index map).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops for both the pytest
oracle checks and the Rust runtime. Real-TPU perf is estimated from the
VMEM footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_EPS = 1e-6


def _rms_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x / jnp.sqrt(ms + eps) * w_ref[...]


def rms_norm(x, w, *, eps=DEFAULT_EPS, block_rows=8, interpret=True):
    """RMS-normalize the last dim of ``x: [s, h]`` with weight ``w: [h]``."""
    s, h = x.shape
    if s % block_rows != 0:
        block_rows = s  # degenerate single-tile fallback for small inputs
    grid = (s // block_rows,)
    return pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, h), x.dtype),
        interpret=interpret,
    )(x, w)


def vmem_footprint_bytes(block_rows, hidden, dtype_bytes=4):
    """Static VMEM estimate per grid step: x tile + w + out tile + ms column.

    Used by DESIGN.md §Perf: with the default (8, 4096) f32 tile this is
    8·4096·4 · 2 + 4096·4 + 8·4 ≈ 278 KiB — far below the ~16 MiB VMEM
    budget, so block_rows can grow to ~240 before spilling.
    """
    tile = block_rows * hidden * dtype_bytes
    return 2 * tile + hidden * dtype_bytes + block_rows * dtype_bytes
