"""Pure-jnp oracles for the Pallas kernels — the correctness reference the
pytest suite asserts against (and the semantics the Rust lemma library and
custom-op registry replicate for `pallas_rms_norm` / `pallas_attention`)."""

import jax.numpy as jnp


def rms_norm_ref(x, w, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * w


def attention_ref(q, k, v):
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T) / jnp.sqrt(jnp.float32(d))
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.matmul(p, v)


def rope_ref(x, cos, sin):
    """Rotate-half RoPE, matching the Rust Op::Rope semantics."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    rot = jnp.concatenate([-x2, x1], axis=-1)
    return x * cos + rot * sin
