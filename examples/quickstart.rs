//! Quickstart: the paper's Figure 1/2 running example.
//!
//! `G_s` computes `F = matmul(A, B) - E`; `G_d` distributes the matmul over
//! two ranks (inner-dim split + reduce-scatter) and subtracts sequence
//! shards of E. GraphGuard infers the clean output relation, which we also
//! numerically certify.
//!
//! Run: `cargo run --example quickstart`

use graphguard::expr::print::{render, Namer};
use graphguard::infer::verify_numeric;
use graphguard::Verifier;
use graphguard::ir::Graph;
use graphguard::relation::Relation;
use graphguard::util::json::Json;

fn main() -> anyhow::Result<()> {
    // --- the sequential specification (Figure 1, left) ---
    let mut gs = Graph::new("fig1_gs");
    let a = gs.input("A", vec![4, 6]);
    let b = gs.input("B", vec![6, 4]);
    let e = gs.input("E", vec![4, 4]);
    let c = gs.matmul("C", a, b);
    let f = gs.sub2("F", c, e);
    gs.mark_output(f);

    // --- the distributed implementation (Figure 1, right) ---
    let mut gd = Graph::new("fig1_gd");
    let a1 = gd.input("A_1", vec![4, 3]);
    let a2 = gd.input("A_2", vec![4, 3]);
    let b1 = gd.input("B_1", vec![3, 4]);
    let b2 = gd.input("B_2", vec![3, 4]);
    let e1 = gd.input("E_1", vec![2, 4]);
    let e2 = gd.input("E_2", vec![2, 4]);
    let c1 = gd.matmul("C_1", a1, b1);
    let c2 = gd.matmul("C_2", a2, b2);
    let d1 = gd.reduce_scatter("D_1", vec![c1, c2], 0, 0);
    let d2 = gd.reduce_scatter("D_2", vec![c1, c2], 0, 1);
    let f1 = gd.sub2("F_1", d1, e1);
    let f2 = gd.sub2("F_2", d2, e2);
    let f_full = gd.all_gather("F_full", vec![f1, f2], 0);
    gd.mark_output(f_full);

    // --- the user-provided clean input relation R_i ---
    let ri = Relation::from_json(
        &Json::parse(
            r#"{
            "A": ["concat(A_1, A_2; dim=1)"],
            "B": ["concat(B_1, B_2; dim=0)"],
            "E": ["concat(E_1, E_2; dim=0)"]
        }"#,
        )
        .unwrap(),
        &gs,
        &gd,
    )?;

    println!("checking that {} refines {} ...\n", gd.name, gs.name);
    let out = Verifier::new().expect(&gs, &gd, &ri)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let namer = Namer { gs: &gs, gd: &gd };
    println!("clean output relation R_o:");
    for &o in &gs.outputs {
        for cand in out.relation.get(o) {
            println!("  {} = {}", gs.tensor(o).name, render(&cand.expr, &namer));
        }
    }
    println!("\nintermediate mappings discovered along the way:");
    let c_id = gs.tensor_by_name("C").unwrap();
    for cand in out.relation_full.get(c_id) {
        println!("  C = {}", render(&cand.expr, &namer));
    }

    verify_numeric(&gs, &gd, &ri, &out.relation, 2024)?;
    println!("\nnumeric certificate: R_o reconstructs G_s outputs exactly ✓");
    println!(
        "({} lemma applications across {} operators)",
        out.stats.total_applications(),
        gs.num_nodes()
    );
    Ok(())
}
