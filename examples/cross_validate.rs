//! End-to-end driver proving all three layers compose.
//!
//! 1. **L1/L2 (build time)**: `make artifacts` lowered the JAX Llama block
//!    (with its Pallas RMSNorm + attention kernels, interpret-lowered) and
//!    the HF-style regression pair to HLO text, and captured their jaxprs
//!    to GraphGuard graph JSON.
//! 2. **L3 (static)**: load the captured `G_s`/`G_d` graphs and the user
//!    `R_i`, run iterative relation inference, obtain `R_o`.
//! 3. **Runtime (dynamic)**: compile both HLO artifacts on the PJRT CPU
//!    client, execute them on the recorded example inputs, evaluate the
//!    inferred `R_o` expression over `G_d`'s outputs with the Rust
//!    expression interpreter, and assert it reproduces `G_s`'s outputs.
//!
//! Run: `make artifacts && cargo run --release --example cross_validate`

use anyhow::{ensure, Context, Result};
use graphguard::expr::eval::{eval_expr, Env};
use graphguard::expr::TensorRef;
use graphguard::Verifier;
use graphguard::ir::{json_io, Graph};
use graphguard::relation::Relation;
use graphguard::runtime::Runtime;
use graphguard::util::json::Json;
use graphguard::util::ndarray::NdArray;
use std::time::Instant;

fn load_json(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

fn load_graph(path: &str) -> Result<Graph> {
    json_io::from_json(&load_json(path)?)
}

fn load_inputs(path: &str) -> Result<Vec<NdArray>> {
    load_json(path)?
        .as_arr()
        .context("inputs file must be a list")?
        .iter()
        .map(|entry| {
            let shape: Vec<i64> = entry
                .get("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .filter_map(|d| d.as_i64())
                .collect();
            let data: Vec<f32> = entry
                .get("data")
                .as_arr()
                .context("data")?
                .iter()
                .filter_map(|v| v.as_f64().map(|f| f as f32))
                .collect();
            NdArray::new(shape, data)
        })
        .collect()
}

fn cross_validate(pair: &str, gs_name: &str, gd_name: &str, ri_name: &str) -> Result<()> {
    println!("━━ {pair} ━━");
    let gs = load_graph(&format!("artifacts/graphs/{gs_name}.json"))?;
    let gd = load_graph(&format!("artifacts/graphs/{gd_name}.json"))?;
    let ri = Relation::from_json(&load_json(&format!("artifacts/graphs/{ri_name}.json"))?, &gs, &gd)?;
    ri.validate_shapes(&gs, &gd)?;

    // static: infer R_o on the captured graphs
    let t0 = Instant::now();
    let out = Verifier::new().expect(&gs, &gd, &ri)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "  static:  refinement holds in {} ({} G_s ops, {} lemma applications)",
        graphguard::bench::fmt_dur(t0.elapsed()),
        gs.num_nodes(),
        out.stats.total_applications()
    );

    // dynamic: run the AOT artifacts via PJRT
    let rt = Runtime::cpu()?;
    let m_s = rt.load_hlo_text(&format!("artifacts/{gs_name}.hlo.txt"))?;
    let m_d = rt.load_hlo_text(&format!("artifacts/{gd_name}.hlo.txt"))?;
    let in_s = load_inputs(&format!("artifacts/graphs/{gs_name}_inputs.json"))?;
    let in_d = load_inputs(&format!("artifacts/graphs/{gd_name}_inputs.json"))?;
    let t1 = Instant::now();
    let out_s = m_s.execute(&in_s)?;
    let out_d = m_d.execute(&in_d)?;
    println!(
        "  runtime: executed both HLO modules on {} in {}",
        rt.platform(),
        graphguard::bench::fmt_dur(t1.elapsed())
    );

    // reconstruct G_s outputs from G_d outputs via R_o
    let mut env: Env = Env::default();
    for (i, &t) in gd.outputs.iter().enumerate() {
        env.insert(TensorRef::d(t), out_d[i].clone());
    }
    for (i, &o) in gs.outputs.iter().enumerate() {
        let cands = out.relation.get(o);
        ensure!(!cands.is_empty(), "no R_o mapping for output {i}");
        for cand in cands {
            let rebuilt = eval_expr(&cand.expr, &env)?;
            let diff = rebuilt.max_abs_diff(&out_s[i]);
            ensure!(
                rebuilt.allclose(&out_s[i], 1e-4, 1e-5),
                "R_o mapping failed to reconstruct output {i}: |Δ|={diff}"
            );
            println!("  dynamic: R_o reconstructs output '{}' (|Δ| = {diff:.2e}) ✓", gs.tensor(o).name);
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    ensure!(
        std::path::Path::new("artifacts/llama_seq.hlo.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    cross_validate("llama TP=2 (Pallas kernels inside)", "llama_seq", "llama_tp2", "llama_ri")?;
    cross_validate("regression grad-accum k=2", "regression_seq", "regression_ga2", "regression_ri")?;
    println!("\nall layers compose: AOT artifacts ⇄ captured graphs ⇄ inferred relations ✓");
    Ok(())
}
