//! Verify the full Table-2 workload suite — the paper's end-to-end use:
//! every model (GPT/Megatron, Qwen2/vLLM, HF regression, Llama-3, the
//! ByteDance-style MoE block) against its distributed implementation, run
//! through the multi-threaded coordinator, reporting the Fig-4-style table.
//!
//! Run: `cargo run --release --example verify_models [-- --ranks 4]`

use graphguard::coordinator::{report_table, Coordinator};
use graphguard::infer::InferConfig;
use graphguard::models;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let ranks = args
        .iter()
        .position(|a| a == "--ranks")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(2usize);

    println!("verifying the Table-2 suite at parallelism {ranks}...\n");
    let mut jobs = models::table2_workloads(ranks);
    // fwd+bwd variant of the ByteDance-style model (paper's "Bwd" bar)
    let (gs, gd, ri) = models::bytedance::bwd_pair(ranks)?;
    jobs.push(models::Workload {
        name: format!("bytedance_bwd_{ranks}"),
        gs,
        gd,
        ri,
        strategies: vec!["ep"],
    });

    let coord = Coordinator::default();
    let results = coord.run_batch(jobs);
    print!("{}", report_table(&results));

    let total: std::time::Duration = results.iter().map(|r| r.duration).sum();
    println!("\ntotal wall time: {}", graphguard::bench::fmt_dur(total));
    for r in &results {
        if let Some(e) = &r.error {
            println!("\n{}:\n{e}", r.name);
        }
    }
    anyhow::ensure!(results.iter().all(|r| r.ok), "some workloads failed");
    println!("all models refine ✓");
    Ok(())
}
