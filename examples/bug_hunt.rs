//! The §6.2 case studies: inject each of the six real-world bugs, run
//! GraphGuard, and print the localization output a user would debug from.
//!
//! Run: `cargo run --release --example bug_hunt`

use graphguard::bugs;

fn main() -> anyhow::Result<()> {
    let mut detected = 0;
    let mut inspectable = 0;
    for case in bugs::all_cases(true) {
        println!("━━ bug {}: {} ━━", case.id, case.name);
        println!("   {}", case.description);
        let (found, report) = case.run();
        match case.expected_locus {
            Some(locus) => {
                anyhow::ensure!(found, "bug {} escaped detection!", case.id);
                anyhow::ensure!(
                    report.contains(locus),
                    "bug {} localized away from '{locus}'",
                    case.id
                );
                detected += 1;
                println!("   ⇒ DETECTED, localized at '{locus}':");
            }
            None => {
                inspectable += 1;
                println!("   ⇒ refinement holds; inspect the relation/trace (paper bug 5):");
            }
        }
        for line in report.lines().take(12) {
            println!("     {line}");
        }
        // sanity: the FIXED version of the same case must refine
        let fixed = match case.id {
            1 => bugs::bug1_rope_offset(false)?,
            2 => bugs::bug2_aux_loss_scaling(false)?,
            3 => bugs::bug3_pad_slice_mismatch(false)?,
            4 => bugs::bug4_sharded_experts(false)?,
            5 => bugs::bug5_missing_aggregation(false)?,
            _ => bugs::bug6_grad_accum(false)?,
        };
        let (fixed_fails, _) = fixed.run();
        anyhow::ensure!(!fixed_fails, "fixed variant of bug {} still flagged", case.id);
        println!("   (fixed variant refines ✓)\n");
    }
    println!("{detected} bugs detected by refinement failure, {inspectable} via R_o inspection — matching §6.2.");
    Ok(())
}
